"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.entropy_judge import entropy_judge_sweep
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (
    entropy_judge_sweep_reference, mha_reference, ssd_chunked_reference,
    ssd_reference,
)
from repro.kernels.ssd_scan import ssd_chunked

_ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


# --------------------------------------------------------------- flash attn

@pytest.mark.parametrize("b,s,t,h,kh,d", [
    (2, 64, 64, 4, 2, 32),     # GQA 2:1
    (1, 37, 37, 4, 4, 16),     # odd seq (padding path), MHA
    (2, 128, 128, 8, 1, 64),   # MQA
    (1, 16, 80, 4, 2, 32),     # cross-length (q shorter than kv)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, s, t, h, kh, d, dtype):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, kh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, kh, d)), dtype)
    causal = s == t
    ref = mha_reference(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_flash_attention_window(rng, window):
    b, s, h, d = 2, 64, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, d)), jnp.float32)
    ref = mha_reference(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_block_shape_invariance(rng):
    """Result must not depend on the BlockSpec tiling."""
    b, s, h, d = 1, 96, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(16, 16), (32, 16), (16, 32), (96, 96)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)


# --------------------------------------------------------------- ssd scan

@pytest.mark.parametrize("b,l,h,p,g,n,q", [
    (2, 64, 4, 8, 2, 16, 16),
    (1, 50, 4, 8, 1, 16, 16),    # padded tail
    (2, 32, 6, 16, 2, 8, 8),
    (1, 128, 2, 32, 1, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(rng, b, l, h, p, g, n, q, dtype):
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, l, h)), jnp.float32)
    a = -jnp.exp(jnp.asarray(rng.normal(size=(h,)), jnp.float32))
    bm = jnp.asarray(rng.normal(size=(b, l, g, n)), dtype)
    cm = jnp.asarray(rng.normal(size=(b, l, g, n)), dtype)
    y0, h0 = ssd_reference(x, dt, a, bm, cm)
    y1, h1 = ssd_chunked(x, dt, a, bm, cm, chunk=q)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               atol=_ATOL[dtype] * 10, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               atol=_ATOL[dtype] * 10, rtol=5e-2)


def test_ssd_chunked_jnp_matches_sequential_long(rng):
    """The chunked XLA path (production) vs exact recurrence, long seq."""
    b, l, h, p, g, n = 1, 512, 2, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, l, h)), jnp.float32)
    a = -jnp.exp(jnp.asarray(rng.normal(size=(h,)), jnp.float32))
    bm = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    y0, h0 = ssd_reference(x, dt, a, bm, cm)
    y1, h1 = ssd_chunked_reference(x, dt, a, bm, cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=2e-4)


# ----------------------------------------------------------- entropy judge

@pytest.mark.parametrize("m,c,bc", [
    (8, 10, 4), (16, 1000, 128), (10, 517, 64), (32, 4096, 512),
])
def test_entropy_judge_kernel_sweep(rng, m, c, bc):
    p = jnp.asarray(rng.dirichlet(np.full(c, 0.2), size=m), jnp.float32)
    sz = jnp.asarray(rng.integers(10, 500, m), jnp.float32)
    mask = jnp.asarray(rng.random(m) > 0.3, jnp.float32).at[0].set(1.0)
    e0, l0 = entropy_judge_sweep_reference(p, sz, mask)
    e1, l1 = entropy_judge_sweep(p, sz, mask, block_c=bc)
    assert float(jnp.abs(e0 - e1)) < 1e-4
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=1e-4)


def test_entropy_judge_kernel_emptying_convention(rng):
    p = jnp.asarray(rng.dirichlet(np.ones(6), size=3), jnp.float32)
    sz = jnp.ones((3,), jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 0.0])
    e1, l1 = entropy_judge_sweep(p, sz, mask, block_c=4)
    assert float(l1[0]) == -1.0            # removing the last member


# ----------------------------------------------------------- decode attn

@pytest.mark.parametrize("t,h,kh,d,win", [
    (64, 4, 2, 32, 0), (40, 8, 8, 16, 12), (100, 4, 1, 32, 16),
])
def test_decode_attention_kernel(rng, t, h, kh, d, win):
    from repro.kernels.decode_attention import decode_attention
    b, idx = 2, t - 10
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    tags = jnp.broadcast_to(
        jnp.where(jnp.arange(t) <= idx, jnp.arange(t), -1)[None], (b, t))
    ref = mha_reference(q, k, v, causal=True, window=win, q_offset=idx,
                        kv_positions=tags)
    out = decode_attention(q, k, v, tags, idx, window=win, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_attention_ring_buffer_tags(rng):
    """Ring-buffer semantics: tags are slot->position, unordered."""
    from repro.kernels.decode_attention import decode_attention
    b, t, h, d, idx, win = 1, 32, 2, 16, 100, 24
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    # slots hold positions 69..100 permuted (ring wrap)
    perm = np.random.default_rng(1).permutation(32)
    tags = jnp.asarray((idx - 31 + perm)[None, :], jnp.int32)
    ref = mha_reference(q, k, v, causal=True, window=win, q_offset=idx,
                        kv_positions=tags)
    out = decode_attention(q, k, v, tags, idx, window=win, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
