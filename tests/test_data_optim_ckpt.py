"""Data partitioners (paper Sec. 4.1 heterogeneity cases), optimizers,
checkpointing, sharding rules, HLO analyzer.

Property-based counterparts live in test_optim_properties.py (skipped
when the ``hypothesis`` dev extra is not installed)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.data.partition import (
    label_histogram, partition, stack_clients,
)
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.optim import adamw, sgd


@pytest.fixture(scope="module")
def image_data():
    (x, y), _ = make_image_dataset(num_classes=10, train_per_class=100,
                                   test_per_class=10, hw=8)
    return x, y


def test_case1_single_label(image_data):
    x, y = image_data
    parts = partition("case1", y, 20, 10)
    hist = label_histogram(y, parts, 10)
    assert np.all((hist > 0).sum(axis=1) == 1)       # exactly one label


def test_case2_two_labels_even(image_data):
    x, y = image_data
    parts = partition("case2", y, 20, 10)
    hist = label_histogram(y, parts, 10)
    assert np.all((hist > 0).sum(axis=1) == 2)
    nz = hist[hist > 0].reshape(20, 2)
    np.testing.assert_array_equal(nz[:, 0], nz[:, 1])  # evenly split


def test_case3_dirichlet_heterogeneous(image_data):
    x, y = image_data
    parts = partition("case3", y, 20, 10, beta=0.1)
    hist = label_histogram(y, parts, 10).astype(np.float64)
    # no client lost; all samples assigned at most once
    total = sum(len(p) for p in parts)
    assert total <= len(y)
    assert min(len(p) for p in parts) >= 2
    # beta=0.1 must give skewed clients: dominant label > 50% on average
    frac = (hist.max(axis=1) / np.clip(hist.sum(axis=1), 1, None)).mean()
    assert frac > 0.5


def test_partitions_are_disjoint(image_data):
    x, y = image_data
    for case in ("case1", "case2", "case3"):
        parts = partition(case, y, 10, 10)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


def test_stack_clients_padding(image_data):
    x, y = image_data
    parts = partition("case3", y, 10, 10, beta=0.2)
    data = stack_clients(x, y, parts, batch_multiple=16)
    assert data["x"].shape[1] % 16 == 0
    for i, p in enumerate(parts):
        assert data["w"][i].sum() == len(p)
        np.testing.assert_array_equal(
            data["y"][i][: len(p)], y[p])


def test_token_dataset_domain_skew():
    x, dom = make_token_dataset(vocab_size=256, num_domains=4,
                                docs_per_domain=16, seq_len=64)
    # different domains -> visibly different token histograms
    h0 = np.bincount(x[dom == 0].ravel(), minlength=256)
    h1 = np.bincount(x[dom == 1].ravel(), minlength=256)
    cos = (h0 @ h1) / (np.linalg.norm(h0) * np.linalg.norm(h1))
    assert cos < 0.9


# ------------------------------------------------------------------ optim

def test_sgd_momentum_matches_manual(rng):
    opt = sgd(lr=0.1, momentum=0.5)
    p = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    st_ = opt.init(p)
    p1, st_ = opt.update(g, st_, p)
    p2, st_ = opt.update(g, st_, p1)
    # manual: m1 = g ; m2 = .5 g + g
    manual1 = np.asarray(p["w"]) - 0.1 * np.asarray(g["w"])
    manual2 = manual1 - 0.1 * 1.5 * np.asarray(g["w"])
    np.testing.assert_allclose(np.asarray(p1["w"]), manual1, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p2["w"]), manual2, rtol=1e-6)


def test_adamw_descends_quadratic():
    opt = adamw(lr=0.1)
    p = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, state = opt.update(g, state, p)
    assert float(jnp.abs(p["w"]).max()) < 0.1


# ------------------------------------------------------------------ ckpt

def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": {"b": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)},
            "c": jnp.arange(5, dtype=jnp.int32)}
    save(str(tmp_path), 7, tree, meta={"note": "x"})
    save(str(tmp_path), 9, jax.tree.map(lambda x: x + 1, tree))
    restored, meta, step = restore(str(tmp_path), tree)
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["a"]["b"]),
                               np.asarray(tree["a"]["b"]) + 1)


def test_checkpoint_retention(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in range(6):
        save(str(tmp_path), s, tree, keep=3)
    files = sorted(os.listdir(tmp_path))
    assert len([f for f in files if f.endswith(".npz")]) == 3


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"w": jnp.zeros((3,))})


# ------------------------------------------------------------ hlo analysis

def test_hlo_analyzer_counts_loop_iterations():
    from repro.launch.hlo_analysis import analyze_hlo_text

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    res = analyze_hlo_text(compiled.as_text())
    assert res["flops"] == pytest.approx(5 * 2 * 8 * 16 * 16, rel=0.01)
