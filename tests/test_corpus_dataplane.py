"""The device-resident ``ClientCorpus`` data plane: paper-scale (N=100)
partition exactness, bit-for-bit stack_clients round-trips and golden
parity through the corpus-backed path, uint8 ingest + on-device
normalization, dynamic data queues (schedule + selector + speculation
transparency), the bounded dirichlet resampler, and tail-batch eval
padding."""
import json
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.corpus import ClientCorpus, DataQueue, Normalize
from repro.data.ingest import (
    cifar10_normalizer, load_cifar10, load_image_corpus,
)
from repro.data.partition import (
    partition, partition_dirichlet, stack_clients,
)
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "seed_history.json")

PAPER_N, CLASSES = 100, 10


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


@pytest.fixture(scope="module")
def paper_labels():
    """A CIFAR-10-shaped label vector (paper N=100 scale, 500/class)."""
    return np.random.default_rng(0).permutation(
        np.repeat(np.arange(CLASSES, dtype=np.int32), 500))


# ------------------------------------------------- paper-scale partitioning

@pytest.mark.parametrize("case", ["case1", "case2", "dirichlet"])
def test_paper_scale_partition_exactness(paper_labels, case):
    """N=100: every sample assigned at most once; remainders accounted."""
    y = paper_labels
    parts = partition(case, y, PAPER_N, CLASSES, seed=0)
    assert len(parts) == PAPER_N
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx)          # at most once
    assert allidx.min() >= 0 and allidx.max() < len(y)
    for p in parts:
        assert len(p) > 0
    if case == "dirichlet":
        # dirichlet splits the class pools exactly: nothing left over
        assert len(allidx) == len(y)
    else:
        # per-class floor-division shares: remainder < users-per-class
        leftovers = len(y) - len(allidx)
        users = 2 * PAPER_N if case == "case2" else PAPER_N
        assert 0 <= leftovers < users


def test_dirichlet_infeasible_fails_loudly(paper_labels):
    """A bad (beta, min_samples) combination raises instead of hanging."""
    with pytest.raises(RuntimeError, match="min_samples|resamples"):
        partition_dirichlet(paper_labels[:200], 100, CLASSES, beta=0.05,
                            seed=0, min_samples=50, max_retries=3)


def test_dirichlet_bounded_keeps_stream(paper_labels):
    """The retry bound must not change feasible draws (same RNG stream)."""
    a = partition_dirichlet(paper_labels, 20, CLASSES, seed=7)
    b = partition_dirichlet(paper_labels, 20, CLASSES, seed=7,
                            max_retries=5)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)


# --------------------------------------------------- corpus round-tripping

def test_corpus_roundtrips_stack_clients(paper_labels):
    """ClientCorpus.from_parts == stack_clients bit-for-bit, N=100."""
    y = paper_labels
    x = np.random.default_rng(1).normal(
        size=(len(y), 8, 8, 3)).astype(np.float32)
    parts = partition("case1", y, PAPER_N, CLASSES, seed=0)
    stacked = stack_clients(x, y, parts, batch_multiple=10)
    corpus = ClientCorpus.from_parts(x, y, parts, batch_multiple=10)
    host = corpus.as_numpy()
    assert set(host) == set(stacked)
    for k in stacked:
        assert host[k].dtype == stacked[k].dtype
        np.testing.assert_array_equal(host[k], stacked[k])
    # cohort gather == host slice, bit-for-bit
    idx = np.array([5, 93, 0, 41])
    got = corpus.cohort(idx)
    for k in stacked:
        np.testing.assert_array_equal(np.asarray(got[k]), stacked[k][idx])
    # an already-device idx is used as-is: the gather moves zero host
    # bytes (the dataplane bench's regression tripwire, as a tier-1 test)
    didx = jax.device_put(jnp.asarray(idx, jnp.int32))
    corpus.cohort(didx)                       # compile outside the guard
    with jax.transfer_guard("disallow"):
        got2 = corpus.cohort(didx)
    for k in stacked:
        np.testing.assert_array_equal(np.asarray(got2[k]), stacked[k][idx])
    # Mapping surface survives for seed-era call sites
    assert corpus["y"].shape == (PAPER_N, stacked["y"].shape[1])
    assert sorted(corpus) == sorted(stacked)
    assert ClientCorpus.from_stacked(corpus) is corpus


def test_corpus_control_plane_stats(paper_labels):
    y = paper_labels[:1000]
    x = np.zeros((len(y), 4, 4, 1), np.float32)
    parts = partition("case1", y, 10, CLASSES, seed=0)
    corpus = ClientCorpus.from_parts(x, y, parts)
    from repro.core.pools import label_histograms
    stacked = stack_clients(x, y, parts)
    np.testing.assert_array_equal(
        corpus.label_histograms(),
        label_histograms(stacked["y"], stacked["w"]))
    assert corpus.label_histograms() is corpus.label_histograms()  # cached
    # the cache is keyed on num_classes: an explicit column count must
    # not serve (or be poisoned by) the inferred-width entry
    wide = corpus.label_histograms(num_classes=CLASSES + 3)
    assert wide.shape[1] == CLASSES + 3
    assert corpus.label_histograms().shape[1] == CLASSES
    np.testing.assert_array_equal(corpus.sizes(),
                                  stacked["w"].sum(axis=1).astype(np.int64))
    # case1: single-label clients -> zero label entropy
    np.testing.assert_allclose(corpus.label_entropy(), 0.0, atol=1e-12)


def test_corpus_uint8_ingest_normalizes_on_device():
    rng = np.random.default_rng(0)
    xu = rng.integers(0, 256, size=(120, 8, 8, 3), dtype=np.uint8)
    yu = rng.integers(0, 4, size=120).astype(np.int32)
    parts = partition("case1", yu, 8, 4, seed=0)
    norm = cifar10_normalizer()
    c8 = ClientCorpus.from_parts(xu, yu, parts, batch_multiple=5,
                                 transform=norm)
    cf = ClientCorpus.from_parts(
        np.asarray(norm(jnp.asarray(xu))), yu, parts, batch_multiple=5)
    assert c8["x"].dtype == jnp.uint8                 # storage dtype kept
    assert c8.nbytes * 3.5 < cf.nbytes                # ~4x smaller resident
    idx = np.array([2, 7, 0])
    a, b = c8.cohort(idx), cf.cohort(idx)
    assert a["x"].dtype == jnp.float32                # normalized cohort
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    valid = np.asarray(a["w"]) > 0                    # pad rows differ by
    np.testing.assert_allclose(                       # construction
        np.asarray(a["x"])[valid], np.asarray(b["x"])[valid], atol=1e-6)
    # host-slice baseline bytes: float32 x regardless of storage dtype
    assert c8.cohort_nbytes(3) == cf.cohort_nbytes(3)


def test_corpus_shard_single_device_mesh(tiny):
    from repro.fl.runtime import make_client_mesh
    data, _ = tiny
    corpus = ClientCorpus.from_stacked(data)
    mesh = make_client_mesh()
    assert corpus.shard(mesh) is corpus
    corpus.shard(mesh)                                # idempotent
    got = corpus.cohort(np.array([1, 3]))
    np.testing.assert_array_equal(np.asarray(got["y"]),
                                  np.asarray(data["y"])[[1, 3]])


# ------------------------------------------------------- dynamic data queue

def test_data_queue_schedule_monotone():
    q = DataQueue(start_frac=0.25, rounds_to_full=10)
    sizes = np.array([100, 40, 7, 1])
    prev = np.zeros_like(sizes)
    for r in range(12):
        act = q.active(r, sizes)
        assert np.all(act >= prev) and np.all(act >= 1)
        assert np.all(act <= sizes)
        prev = act
    np.testing.assert_array_equal(q.active(10, sizes), sizes)  # full set
    np.testing.assert_array_equal(q.active(99, sizes), sizes)
    staged = DataQueue(start_frac=0.25, rounds_to_full=8, growth="staged",
                       stages=4)
    fracs = {staged.frac(r) for r in range(9)}
    assert len(fracs) == 5                       # start + 4 graduation steps
    with pytest.raises(ValueError, match="linear.*staged"):
        DataQueue(growth="Staged")


def test_cohort_queue_mask(tiny):
    data, _ = tiny
    corpus = ClientCorpus.from_stacked(data)
    idx = np.array([0, 4, 6])
    active = np.array([3, 20, 0])
    got = corpus.cohort(idx, active=active)
    w = np.asarray(data["w"])[idx]
    s = w.shape[1]
    expect = w * (np.arange(s)[None, :] < active[:, None])
    np.testing.assert_array_equal(np.asarray(got["w"]), expect)
    # x/y untouched; no queue -> w untouched
    np.testing.assert_array_equal(np.asarray(got["x"]),
                                  np.asarray(data["x"])[idx])
    plain = corpus.cohort(idx)
    np.testing.assert_array_equal(np.asarray(plain["w"]), w)


def test_queue_selector_ranks_and_schedules(tiny):
    data, _ = tiny
    corpus = ClientCorpus.from_stacked(data)
    sel = fl.QueueSelector(8, eps=1.0, seed=0,
                           queue=DataQueue(start_frac=0.5,
                                           rounds_to_full=4))
    sel.bind_data(corpus)
    picks = sel.select(4)
    # case1 clients all have zero label entropy: pure exploit ranks by
    # (score, id) and the first round is the lowest ids
    assert picks == [0, 1, 2, 3]
    act = sel.data_schedule(picks)
    assert act is not None and len(act) == 4
    assert np.all(act <= corpus.sizes()[picks])
    # fairness: exploiting twice must rotate to unvisited clients
    second = sel.select(4)
    assert set(second).isdisjoint(picks)
    # unbound selector: uniform fallback, no schedule
    blank = fl.QueueSelector(8, seed=0)
    assert len(set(blank.select(4))) == 4
    assert blank.data_schedule([0, 1, 2, 3]) is None
    assert blank.stats()["selector"] == "queue"


def test_queue_selector_speculation_transparent(tiny):
    """fedentropy+queue: the pipelined speculative engine reproduces the
    sequential server's history exactly (schedule rides the selector copy
    the same way FedCAT groups do)."""
    data, params = tiny
    cfg = fl.ServerConfig(num_clients=8, participation=0.5, seed=0)
    local = LocalSpec(epochs=1, batch_size=20)
    seq = fl.build("fedentropy+queue", cnn.apply, params, data, cfg, local)
    spec = fl.build("fedentropy+queue", cnn.apply, params, data, cfg, local,
                    engine="pipelined", runtime=RuntimeConfig(speculate=True))
    for _ in range(3):
        seq.round()
        spec.round()
    for a, b in zip(seq.history, spec.history):
        assert a["selected"] == b["selected"]
        assert a["positive"] == b["positive"]
        # bit-level on one device; across a forced multi-device mesh the
        # sharded engine's fan-out is a different compiled program shape,
        # where CPU XLA floats are not bitwise-stable (ints stay exact)
        atol = 1e-12 if len(jax.devices()) == 1 else 1e-6
        assert a["entropy"] == pytest.approx(b["entropy"], abs=atol)
    # the queue actually withheld data early on: round-0 cohort trained on
    # fewer effective samples than the full shard
    act = seq.selector.queue.active(0, seq.corpus.sizes())
    assert np.all(act < seq.corpus.sizes())


# --------------------------------------------- golden via explicit corpus

def test_golden_via_explicit_corpus(tiny):
    """An explicitly constructed ClientCorpus (not a dict) feeds the same
    bit-for-bit history the goldens recorded."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedentropy"]
    data, params = tiny
    corpus = ClientCorpus.from_stacked(data)
    server = fl.build("fedentropy", cnn.apply, params, corpus,
                      fl.ServerConfig(num_clients=8, participation=0.5,
                                      seed=0),
                      LocalSpec(epochs=1, batch_size=20))
    assert server.corpus is corpus
    for _ in range(3):
        server.round()
    for g, w in zip(server.history, golden["history"][:3]):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["entropy"] == pytest.approx(float(w["entropy"]), abs=1e-9)


# ------------------------------------------------------- eval tail padding

def test_evaluate_pads_tail_batch(tiny):
    data, params = tiny
    server = fl.build("fedavg", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=0.5,
                                      seed=0),
                      LocalSpec(epochs=1, batch_size=20))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(70, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, size=70).astype(np.int32))
    whole = server.evaluate(x, y, batch=70)
    tail = server.evaluate(x, y, batch=32)        # 32 + 32 + 6
    assert tail["accuracy"] == pytest.approx(whole["accuracy"], abs=1e-6)
    assert tail["loss"] == pytest.approx(whole["loss"], rel=1e-5)
    # one compiled program per batch shape, tail included
    f = server._eval_fn()
    cache_size = getattr(f, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 2                  # (70,...) and (32,...)


# ------------------------------------------------------------ CIFAR ingest

def _write_fake_cifar(root):
    d = os.path.join(root, "cifar-10-batches-py")
    os.makedirs(d, exist_ok=True)
    rng = np.random.default_rng(0)
    for name, n in [(f"data_batch_{i}", 20) for i in range(1, 6)] + \
            [("test_batch", 10)]:
        blob = {b"data": rng.integers(0, 256, size=(n, 3072),
                                      dtype=np.uint8),
                b"labels": rng.integers(0, 10, size=n).tolist()}
        with open(os.path.join(d, name), "wb") as f:
            pickle.dump(blob, f)
    return d


def test_load_cifar10_pickle_batches(tmp_path):
    d = _write_fake_cifar(str(tmp_path))
    (xtr, ytr), (xte, yte) = load_cifar10(str(tmp_path))
    assert xtr.shape == (100, 32, 32, 3) and xtr.dtype == np.uint8
    assert ytr.shape == (100,) and ytr.dtype == np.int32
    assert xte.shape == (10, 32, 32, 3)
    # the batches dir itself also resolves
    (x2, _), _ = load_cifar10(d)
    np.testing.assert_array_equal(x2, xtr)
    # CHW-flat -> HWC transpose: channel planes land in the last axis
    with open(os.path.join(d, "data_batch_1"), "rb") as f:
        raw = pickle.load(f, encoding="bytes")[b"data"]
    np.testing.assert_array_equal(
        xtr[0], raw[0].reshape(3, 32, 32).transpose(1, 2, 0))


def test_load_image_corpus_sources(tmp_path):
    src = load_image_corpus(None, num_classes=4, train_per_class=10,
                            test_per_class=5)
    assert src.source == "synthetic" and src.transform is None
    assert src.train[0].dtype == np.float32
    _write_fake_cifar(str(tmp_path))
    real = load_image_corpus(str(tmp_path))
    assert real.source == "cifar10" and real.num_classes == 10
    assert real.train[0].dtype == np.uint8
    assert isinstance(real.transform, Normalize)
    with pytest.raises(FileNotFoundError, match="CIFAR-10"):
        load_cifar10(str(tmp_path / "nowhere"))
