"""The traced eps-greedy pools (``core.pools``) and their selector
(``pools-traced``): draw semantics (pool pick, spillover, removal),
verdict re-filing, host-selector vs raw-jitted-stream equality (the
invariant the scan engine's pool fold rests on), the fold surface, and
an ``lmstep`` client-rule smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.pools import pools_draw, pools_refile
from repro.core.strategies import LocalSpec
from repro.fl.selectors import TracedPoolSelector

N = 10


def _masks(pos_ids, n=N):
    pos = np.zeros(n, np.float32)
    pos[list(pos_ids)] = 1.0
    return jnp.asarray(pos), jnp.asarray(1.0 - pos)


# ------------------------------------------------------------ pools_draw

def test_draw_eps_one_stays_in_positive_pool():
    pos, neg = _masks(range(6))
    for seed in range(8):
        sel, _ = pools_draw(jax.random.PRNGKey(seed), pos, neg,
                            num=4, eps=1.0)
        assert set(np.asarray(sel).tolist()) <= set(range(6))


def test_draw_eps_zero_stays_in_negative_pool():
    pos, neg = _masks(range(6))          # negatives are 6..9
    for seed in range(8):
        sel, _ = pools_draw(jax.random.PRNGKey(seed), pos, neg,
                            num=4, eps=0.0)
        assert set(np.asarray(sel).tolist()) <= {6, 7, 8, 9}


def test_draw_spills_into_other_pool():
    """Sec. 3.4: a too-small chosen pool contributes ALL its members and
    the remainder comes from the other pool."""
    pos, neg = _masks({1, 4})
    for seed in range(8):
        sel, _ = pools_draw(jax.random.PRNGKey(seed), pos, neg,
                            num=5, eps=1.0)
        chosen = set(np.asarray(sel).tolist())
        assert len(chosen) == 5           # no repeats: without replacement
        assert {1, 4} <= chosen           # whole positive pool first


def test_draw_is_deterministic_and_advances_key():
    pos, neg = _masks(range(5))
    key = jax.random.PRNGKey(0)
    sel_a, key_a = pools_draw(key, pos, neg, num=3, eps=0.8)
    sel_b, key_b = pools_draw(key, pos, neg, num=3, eps=0.8)
    assert np.array_equal(np.asarray(sel_a), np.asarray(sel_b))
    assert np.array_equal(np.asarray(key_a), np.asarray(key_b))
    assert not np.array_equal(np.asarray(key_a), np.asarray(key))


# ---------------------------------------------------------- pools_refile

def test_refile_moves_cohort_by_verdict_only():
    pos, neg = _masks(range(6))
    sel = jnp.asarray([2, 7, 5], jnp.int32)
    admitted = jnp.asarray([1.0, 1.0, 0.0])
    new_pos, new_neg = pools_refile(pos, neg, sel, admitted)
    new_pos, new_neg = np.asarray(new_pos), np.asarray(new_neg)
    # cohort re-filed by verdict: 2,7 -> positive, 5 -> negative
    assert new_pos[2] == 1.0 and new_neg[2] == 0.0
    assert new_pos[7] == 1.0 and new_neg[7] == 0.0
    assert new_pos[5] == 0.0 and new_neg[5] == 1.0
    # everyone else untouched
    rest = [i for i in range(N) if i not in (2, 7, 5)]
    assert np.array_equal(new_pos[rest], np.asarray(pos)[rest])
    assert np.array_equal(new_neg[rest], np.asarray(neg)[rest])
    # membership stays a partition
    assert np.array_equal(new_pos + new_neg, np.ones(N, np.float32))


# ------------------------------------------------- TracedPoolSelector

def test_selector_matches_raw_jitted_stream():
    """The invariant the scan fold rests on: the host selector's
    select/update cycle IS pools_draw/pools_refile on the same key
    chain — bit-for-bit, many rounds."""
    sel_host = TracedPoolSelector(N, eps=0.8, seed=3)
    key = jax.random.PRNGKey(3)
    pos, neg = _masks(range(N))
    for r in range(12):
        chosen = sel_host.select(4)
        raw, key = pools_draw(key, pos, neg, num=4, eps=0.8)
        assert chosen == [int(c) for c in np.asarray(raw)]
        admitted = jnp.asarray([(r + i) % 2 for i in range(4)], jnp.float32)
        pos, neg = pools_refile(pos, neg, raw, admitted)
        pos_ids = [c for i, c in enumerate(chosen) if (r + i) % 2]
        neg_ids = [c for i, c in enumerate(chosen) if not (r + i) % 2]
        sel_host.update(pos_ids, neg_ids)
        hpos, hneg = sel_host._masks()
        assert np.array_equal(np.asarray(hpos), np.asarray(pos))
        assert np.array_equal(np.asarray(hneg), np.asarray(neg))


def test_selector_select_removes_cohort_until_update():
    sel = TracedPoolSelector(N, eps=0.8, seed=0)
    chosen = sel.select(4)
    assert len(chosen) == len(set(chosen)) == 4
    assert sel.positive.isdisjoint(chosen)
    assert sel.negative.isdisjoint(chosen)
    sel.update(chosen[:1], chosen[1:])
    assert set(chosen[:1]) <= sel.positive
    assert set(chosen[1:]) <= sel.negative


def test_fold_drawn_mirrors_select():
    """fold_drawn(sel, key_after) leaves the selector in exactly the
    state select() would have."""
    a = TracedPoolSelector(N, eps=0.8, seed=7)
    b = TracedPoolSelector(N, eps=0.8, seed=7)
    for _ in range(4):
        key, pos, neg = b.fold_carry()
        raw, key_after = pools_draw(key, pos, neg, num=4, eps=0.8)
        chosen = a.select(4)
        b.fold_drawn(raw, key_after)
        assert chosen == [int(c) for c in np.asarray(raw)]
        assert a.positive == b.positive and a.negative == b.negative
        assert np.array_equal(np.asarray(a._key), np.asarray(b._key))
        a.update(chosen[:2], chosen[2:])
        b.update(chosen[:2], chosen[2:])


def test_selector_registered_and_stats():
    sel = fl.get("selector", "pools-traced")(N, eps=0.5, seed=0)
    assert isinstance(sel, TracedPoolSelector)
    s = sel.stats()
    assert s["selector"] == "pools-traced"
    assert s["positive"] == N and s["negative"] == 0


# ------------------------------------------------------- lmstep strategy

def _toy_lm_apply(params, x):
    h = params["emb"][x[:, :-1]]              # (S, L, d)
    logits = h @ params["out"]                # (S, L, V)
    return logits, h[:, -1, :]


def test_lmstep_client_soft_label_is_distribution():
    V, d, S, L = 11, 5, 6, 4
    rng = np.random.default_rng(0)
    params = {"emb": jnp.asarray(rng.normal(size=(V, d)), jnp.float32),
              "out": jnp.asarray(rng.normal(size=(d, V)), jnp.float32)}
    strat = fl.LMWindowStrategy(
        LocalSpec(lr=0.1, momentum=0.5, epochs=2, batch_size=3))
    assert strat.name == "lmstep"
    assert getattr(strat, "prepare_round", None) is None
    client = jax.jit(strat.make_client_fn(_toy_lm_apply))
    x = jnp.asarray(rng.integers(0, V, size=(2, S, L + 1)), jnp.int32)
    w = jnp.asarray([[1, 1, 1, 1, 0, 0], [1, 1, 1, 1, 1, 1]], jnp.float32)
    out = client(params, {"x": x, "w": w}, None, None, None)
    assert out["soft_label"].shape == (2, V)
    assert out["params"]["emb"].shape == (2, V, d)
    np.testing.assert_allclose(np.asarray(out["size"]),
                               [4.0, 6.0], rtol=1e-6)
    # Eq. 2 LM analog: a weighted mean of softmax rows sums to one
    np.testing.assert_allclose(
        np.asarray(jnp.sum(out["soft_label"], -1)), [1.0, 1.0], atol=1e-5)
    # training moved the params
    assert float(jnp.max(jnp.abs(out["params"]["out"][0]
                                 - params["out"]))) > 0.0


def test_lmstep_padded_windows_do_not_train():
    """Zero-weight (padded) windows contribute neither gradient nor soft
    label: appending them changes nothing."""
    V, d, S, L = 7, 4, 4, 3
    rng = np.random.default_rng(1)
    params = {"emb": jnp.asarray(rng.normal(size=(V, d)), jnp.float32),
              "out": jnp.asarray(rng.normal(size=(d, V)), jnp.float32)}
    strat = fl.LMWindowStrategy(
        LocalSpec(lr=0.1, momentum=0.0, epochs=1, batch_size=8))
    client = strat.make_client_fn(_toy_lm_apply)
    x = jnp.asarray(rng.integers(0, V, size=(1, S, L + 1)), jnp.int32)
    w = jnp.ones((1, S), jnp.float32)
    xp = jnp.concatenate([x, jnp.zeros((1, 2, L + 1), jnp.int32)], axis=1)
    wp = jnp.concatenate([w, jnp.zeros((1, 2), jnp.float32)], axis=1)
    a = client(params, {"x": x, "w": w}, None, None, None)
    b = client(params, {"x": xp, "w": wp}, None, None, None)
    np.testing.assert_allclose(np.asarray(a["soft_label"]),
                               np.asarray(b["soft_label"]), atol=1e-6)
    for la, lb in zip(jax.tree.leaves(a["params"]),
                      jax.tree.leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-6)
    np.testing.assert_allclose(np.asarray(a["size"]),
                               np.asarray(b["size"]))


def test_lmstep_folds_under_scan():
    """lmstep is stateless with no group dispatch: fedentropy-traced +
    lmstep folds R>1 (the LM composition the example runs)."""
    V, d, S, L, C = 7, 4, 4, 3, 4
    rng = np.random.default_rng(2)
    params = {"emb": jnp.asarray(rng.normal(size=(V, d)), jnp.float32),
              "out": jnp.asarray(rng.normal(size=(d, V)), jnp.float32)}
    x = jnp.asarray(rng.integers(0, V, size=(C, S, L + 1)), jnp.int32)
    data = {"x": x, "y": x[:, :, -1],
            "w": jnp.ones((C, S), jnp.float32)}
    server = fl.build(
        "fedentropy-traced", _toy_lm_apply, params, data,
        fl.ServerConfig(num_clients=C, participation=0.5, seed=0),
        LocalSpec(lr=0.1, momentum=0.0, epochs=1, batch_size=4),
        strategy="lmstep", engine="scan",
        runtime=fl.ScanConfig(rounds_per_scan=2, params_mode="remat"))
    assert server.scan_rounds() == 2
    assert server.fallback_reasons == []
    rec = server.round()
    assert rec["selected"] and "scan_fallback" not in rec
    assert np.isfinite(rec["entropy"]) or np.isnan(rec["entropy"])


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
