import os

# Tests run against the single real CPU device (the dry-run — and ONLY the
# dry-run — forces 512 host devices via its own module-level XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
