"""Hypothesis properties of the streaming data plane (ISSUE satellite):

(a) `HostCorpus` streamed histograms/entropy/sizes match `ClientCorpus`
    dense stats **bit-exactly** over random small corpora — any client
    count, sample count, class count, 0/1 weight mask, and stats chunk
    size (including chunk sizes that split every boundary);
(b) cohorts are bit-equal across planes for random index vectors (with
    repeats) and random queue masks;
(c) `as_data_plane("auto")` respects the residency budget exactly.

The deterministic fixed-seed twins live in tests/test_stream_dataplane
.py and run everywhere hypothesis is absent (locally the tier-1 suite
skips this module; CI's dev extra installs hypothesis and runs it).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.corpus import ClientCorpus, Normalize  # noqa: E402
from repro.data.stream import HostCorpus, as_data_plane  # noqa: E402


def _corpus(rng, n, s, c):
    """A stacked dict with the stack_clients contract: 0/1 float32 w."""
    return {
        "x": rng.integers(0, 256, (n, s, 3), dtype=np.uint8),
        "y": rng.integers(0, c, (n, s)).astype(np.int32),
        "w": (rng.random((n, s)) < 0.8).astype(np.float32),
    }


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 24),
       s=st.integers(1, 12), c=st.integers(2, 12),
       chunk=st.integers(1, 30))
def test_streamed_stats_bit_exact(seed, n, s, c, chunk):
    rng = np.random.default_rng(seed)
    data = _corpus(rng, n, s, c)
    dense = ClientCorpus.from_stacked(dict(data))
    streamed = HostCorpus(dict(data), stats_chunk=chunk)
    np.testing.assert_array_equal(streamed.sizes(), dense.sizes())
    np.testing.assert_array_equal(streamed.label_histograms(),
                                  dense.label_histograms())
    np.testing.assert_array_equal(streamed.label_entropy(),
                                  dense.label_entropy())
    np.testing.assert_array_equal(streamed.label_histograms(c + 3),
                                  dense.label_histograms(c + 3))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 16),
       s=st.integers(2, 10), m=st.integers(1, 8),
       queued=st.booleans(), transform=st.booleans())
def test_cohorts_bit_equal_across_planes(seed, n, s, m, queued, transform):
    rng = np.random.default_rng(seed)
    data = _corpus(rng, n, s, 4)
    t = Normalize(scale=1 / 255.0, mean=(0.4, 0.5, 0.6),
                  std=(0.2, 0.3, 0.4)) if transform else None
    dense = ClientCorpus(dict(data), transform=t)
    streamed = HostCorpus(dict(data), transform=t)
    idx = rng.integers(0, n, m)                     # repeats allowed
    active = rng.integers(0, s + 1, m) if queued else None
    a = dense.cohort(idx, active=active)
    b = streamed.cohort(idx, active=active)
    assert set(a) == set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8),
       budget_slack=st.integers(-1, 1))
def test_auto_plane_respects_budget(seed, n, budget_slack):
    rng = np.random.default_rng(seed)
    data = _corpus(rng, n, 4, 4)
    nbytes = sum(v.nbytes for v in data.values())
    plane = as_data_plane(dict(data),
                          resident_budget=nbytes + budget_slack)
    assert plane.plane == ("streaming" if budget_slack < 0
                           else "resident")
