"""Property-based invariants for the cluster axis and drift schedule.

Requires hypothesis (skipped wholesale when not installed — CI's
forced-8-device job carries it; tests/test_cluster_engine.py holds
deterministic twins of the core claims so local runs without hypothesis
still exercise them).
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.data.partition import drift_schedule, partition, stack_clients  # noqa: E402
from repro.data.synthetic import make_image_dataset  # noqa: E402
from repro.fl.clusters import ModelBank, argmin_assign  # noqa: E402

_SETTINGS = settings(max_examples=25, deadline=None)


@pytest.fixture(scope="module")
def corpus():
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=30, test_per_class=5, hw=8,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=10)
    return xtr, ytr, data


# --------------------------------------------------------- drift_schedule
@_SETTINGS
@given(seed=st.integers(0, 2**31 - 1), at=st.integers(0, 50))
def test_drift_schedule_seed_deterministic(corpus, seed, at):
    """Same (seed, at) -> identical events, arrays included."""
    xtr, ytr, data = corpus
    spc = int(data["y"].shape[1])
    a = drift_schedule(xtr, ytr, 8, 4, at=at, seed=seed,
                       samples_per_client=spc)
    b = drift_schedule(xtr, ytr, 8, 4, at=at, seed=seed,
                       samples_per_client=spc)
    assert len(a) == len(b)
    for ea, eb in zip(a, b):
        assert ea.round == eb.round == at
        assert ea.clients == eb.clients
        assert sorted(ea.data) == sorted(eb.data)
        for k in ea.data:
            np.testing.assert_array_equal(ea.data[k], eb.data[k])


@_SETTINGS
@given(seed=st.integers(0, 2**31 - 1),
       frac=st.floats(0.1, 1.0, allow_nan=False))
def test_drift_schedule_shape_contract(corpus, seed, frac):
    """Events carry distinct in-range clients, one data row per client,
    and every row respects the corpus's fixed per-client sample axis."""
    xtr, ytr, data = corpus
    spc = int(data["y"].shape[1])
    events = drift_schedule(xtr, ytr, 8, 4, at=3, frac=frac, seed=seed,
                            samples_per_client=spc)
    for ev in events:
        assert len(set(ev.clients)) == len(ev.clients) >= 1
        assert all(0 <= c < 8 for c in ev.clients)
        for v in ev.data.values():
            assert np.shape(v)[0] == len(ev.clients)
            assert np.shape(v)[1] == spc


@_SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_drift_schedule_changes_labels(corpus, seed):
    """A drift event actually re-partitions: at least one drifting
    client's label row differs from its pre-drift row."""
    xtr, ytr, data = corpus
    ev = drift_schedule(xtr, ytr, 8, 4, at=1, seed=seed,
                        samples_per_client=int(data["y"].shape[1]))[0]
    before = np.asarray(data["y"])
    after = np.asarray(ev.data["y"])
    assert any(not np.array_equal(after[i], before[c])
               for i, c in enumerate(ev.clients))


# ---------------------------------------------------------- argmin_assign
@_SETTINGS
@given(st.integers(1, 6), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_argmin_assign_partitions(k, m, seed):
    """Every client gets exactly one cluster id in [0, K); K=1 is the
    constant zero map; ties break to the lowest center index."""
    scores = np.random.default_rng(seed).normal(size=(k, m))
    cids = argmin_assign(scores)
    assert cids.shape == (m,)
    assert cids.dtype == np.int64
    assert np.all((cids >= 0) & (cids < k))
    if k == 1:
        np.testing.assert_array_equal(cids, np.zeros(m, np.int64))
    # tie-break: duplicating the winning row at a higher index must not
    # move any assignment upward
    tied = np.concatenate([scores, scores[cids, np.arange(m)][None, :]
                           * np.ones((1, m))], axis=0)
    np.testing.assert_array_equal(argmin_assign(tied), cids)


@_SETTINGS
@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_model_bank_gather_roundtrip(k, seed):
    """gather(cids) row j is bitwise the assigned center's leaves."""
    rng = np.random.default_rng(seed)
    params = {"w": rng.normal(size=(3, 2)).astype(np.float32),
              "b": rng.normal(size=(2,)).astype(np.float32)}
    bank = ModelBank.init(params, k, seed=seed % 997)
    cids = rng.integers(0, k, size=5)
    g = bank.gather(cids)
    for j, c in enumerate(cids):
        for leaf, center in zip(jax.tree.leaves(g),
                                jax.tree.leaves(bank.center(int(c)))):
            np.testing.assert_array_equal(np.asarray(leaf[j]),
                                          np.asarray(center))
