"""Clustered federated learning: the K-center ModelBank axis.

Holds the ISSUE acceptance criteria: ``ifca+maxent`` at K=1 reproduces
the seed golden bit-for-bit (params digest included); the new clustered
golden (K=3, drift at round 2) holds Server == PipelinedServer with
speculation off AND on; the scan engine falls back to R=1 with
machine-readable ``cluster-dispatch``/``drift-schedule`` reasons while
still matching the clustered history; plus deterministic twins of the
hypothesis properties (tests/test_cluster_properties.py) so the
invariants are exercised even where hypothesis isn't installed.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import drift_schedule, partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

GOLDEN_SEED = os.path.join(os.path.dirname(__file__), "golden",
                           "seed_history.json")
GOLDEN_CLUSTER = os.path.join(os.path.dirname(__file__), "golden",
                              "cluster_history.json")

# same tolerance split as tests/test_runtime_engine.py: bitwise on one
# device, entropy tolerance across forced multi-device program shapes
_SINGLE_DEVICE = len(jax.devices()) == 1
ENT_ATOL = 1e-9 if _SINGLE_DEVICE else 1e-6


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return (xtr, ytr), data, params


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def _drift(tiny, at=2, seed=0):
    (xtr, ytr), data, _ = tiny
    return drift_schedule(xtr, ytr, 8, 4, at=at, seed=seed,
                          samples_per_client=int(data["y"].shape[1]))


def _build(tiny, k=3, drift=None, engine=None, runtime=None,
           name="ifca+maxent", **overrides):
    _, data, params = tiny
    kwargs = dict(overrides)
    if engine is not None:
        kwargs["engine"] = engine
    if runtime is not None:
        kwargs["runtime"] = runtime
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0, num_clusters=k),
                    LocalSpec(epochs=1, batch_size=20),
                    drift=drift, **kwargs)


def _assert_matches_cluster_golden(history, golden):
    assert len(history) == len(golden)
    for g, w in zip(history, golden):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        assert g["cluster"] == w["cluster"]
        assert sorted(g["clusters"]) == sorted(w["clusters"])
        for c, v in w["clusters"].items():
            got = g["clusters"][c]
            assert got["members"] == v["members"]
            assert got["positive"] == v["positive"]
            assert got["negative"] == v["negative"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=ENT_ATOL)


# ------------------------------------------------------- K=1 reduction
def test_k1_reduces_to_seed_golden(tiny):
    """ISSUE acceptance: ``ifca+maxent`` with num_clusters=1 IS the seed
    ``fedentropy`` run — same history bit-for-bit, same params digest."""
    with open(GOLDEN_SEED) as f:
        want = json.load(f)["fedentropy"]
    server = _build(tiny, k=1)
    assert server.bank is None            # the unclustered code path
    for _ in range(len(want["history"])):
        server.round()
    for g, w in zip(server.history, want["history"]):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        assert "cluster" not in g
        assert g["entropy"] == pytest.approx(float(w["entropy"]),
                                             abs=ENT_ATOL)
    if _SINGLE_DEVICE:
        assert _params_digest(server.global_params) == \
            float(want["params_digest"])


# --------------------------------------------------- golden equivalence
def test_sequential_matches_cluster_golden(tiny):
    with open(GOLDEN_CLUSTER) as f:
        want = json.load(f)["ifca_maxent_k3_drift"]
    server = _build(tiny, k=3, drift=_drift(tiny))
    for _ in range(len(want["history"])):
        server.round()
    _assert_matches_cluster_golden(server.history, want["history"])
    drift_rounds = [r["round"] for r in server.history if "drift" in r]
    assert drift_rounds == [want["drift_round"]]
    if _SINGLE_DEVICE:
        assert _params_digest(server.bank.stacked) == \
            float(want["params_digest"])


@pytest.mark.parametrize("speculate", [False, True])
def test_pipelined_matches_cluster_golden(tiny, speculate):
    """ISSUE acceptance: PipelinedServer holds the clustered golden with
    speculation off AND on (verdicts always from the float64 oracle)."""
    with open(GOLDEN_CLUSTER) as f:
        want = json.load(f)["ifca_maxent_k3_drift"]
    server = _build(tiny, k=3, drift=_drift(tiny), engine="pipelined",
                    runtime=fl.RuntimeConfig(speculate=speculate))
    for _ in range(len(want["history"])):
        server.round()
    _assert_matches_cluster_golden(server.history, want["history"])
    if _SINGLE_DEVICE:
        assert _params_digest(server.bank.stacked) == \
            float(want["params_digest"])
    if speculate:
        assert all("spec_hit" in r for r in server.history)


def test_pipelined_speculation_never_spans_drift(tiny):
    """No pending dispatch may exist when a drift event applies: the
    round before the drift must not speculatively dispatch (spec_next)."""
    server = _build(tiny, k=3, drift=_drift(tiny, at=2), engine="pipelined",
                    runtime=fl.RuntimeConfig(speculate=True))
    server.round()                         # round 0: may speculate round 1
    server.round()                         # round 1: must NOT dispatch 2
    assert server._pending is None
    rec = server.round()                   # round 2: drift applies here
    assert "drift" in rec


def test_fesem_matches_across_engines(tiny):
    """FeSEM's sticky weight-distance assignment walks the same stream
    sequentially and speculatively (update is verdict-independent)."""
    seq = _build(tiny, k=3, name="fesem", judge="maxent",
                 selector="pools")
    pip = _build(tiny, k=3, name="fesem", judge="maxent",
                 selector="pools", engine="pipelined",
                 runtime=fl.RuntimeConfig(speculate=True))
    for _ in range(4):
        seq.round()
        pip.round()
    for a, b in zip(seq.history, pip.history):
        assert a["selected"] == b["selected"]
        assert a["positive"] == b["positive"]
        assert a["cluster"] == b["cluster"]
    assert seq.cluster.stats() == pip.cluster.stats()
    if _SINGLE_DEVICE:
        assert _params_digest(seq.bank.stacked) == \
            _params_digest(pip.bank.stacked)


# ------------------------------------------------------------ scan axis
def test_scan_falls_back_on_clusters_and_drift(tiny):
    """Satellite: the scan engine refuses to fold clustered/drifted runs
    — R=1 eager rounds, machine-readable reasons, history still equal."""
    with open(GOLDEN_CLUSTER) as f:
        want = json.load(f)["ifca_maxent_k3_drift"]
    server = _build(tiny, k=3, drift=_drift(tiny), engine="scan",
                    runtime=fl.ScanConfig(rounds_per_scan=4))
    assert server.scan_rounds() == 1
    codes = {r["code"] for r in server.fallback_reasons}
    assert "cluster-dispatch" in codes
    assert "drift-schedule" in codes
    for _ in range(len(want["history"])):
        rec = server.round()
        assert "cluster-dispatch" in rec["scan_fallback"]
    _assert_matches_cluster_golden(server.history, want["history"])
    stats = server.stats()
    assert stats["effective_rounds_per_scan"] == 1
    assert {r["code"] for r in stats["fallback_reasons"]} >= \
        {"cluster-dispatch", "drift-schedule"}


def test_scan_does_not_flag_unclustered_runs(tiny):
    server = _build(tiny, k=1, name="fedentropy", engine="scan",
                    runtime=fl.ScanConfig(rounds_per_scan=2))
    server.scan_rounds()
    codes = {r["code"] for r in server.fallback_reasons}
    assert "cluster-dispatch" not in codes
    assert "drift-schedule" not in codes


# ------------------------------------------------------- engine refusals
def test_async_refuses_clusters(tiny):
    with pytest.raises(ValueError, match="ModelBank"):
        _build(tiny, k=3, engine="async", runtime=fl.AsyncConfig())


def test_async_refuses_drift(tiny):
    with pytest.raises(ValueError, match="drift"):
        _build(tiny, k=1, name="fedentropy", drift=_drift(tiny),
               engine="async", runtime=fl.AsyncConfig())


def test_clusters_refuse_stateful_strategy(tiny):
    with pytest.raises(ValueError, match="state"):
        _build(tiny, k=3, name="ifca", strategy="scaffold")


def test_clusters_refuse_chain_strategy(tiny):
    with pytest.raises(ValueError, match="fan-out"):
        _build(tiny, k=3, name="ifca", strategy="catchain")


def test_drift_event_validates_sample_length(tiny):
    _, data, params = tiny
    bad = fl.DriftEvent(round=1, clients=(0,),
                        data={"y": np.zeros((1, 3), np.int32)})
    with pytest.raises(ValueError, match="sample length"):
        fl.build("fedentropy", cnn.apply, params, data,
                 fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
                 LocalSpec(epochs=1, batch_size=20), drift=[bad])


# ------------------------------------------ deterministic property twins
def test_drift_schedule_deterministic(tiny):
    """Twin of the hypothesis property: same seed -> identical events;
    different seed -> different drifting sets or rows."""
    a, b = _drift(tiny, seed=0), _drift(tiny, seed=0)
    assert len(a) == len(b) == 1
    assert a[0].round == b[0].round and a[0].clients == b[0].clients
    for k in a[0].data:
        np.testing.assert_array_equal(a[0].data[k], b[0].data[k])
    c = _drift(tiny, seed=7)[0]
    assert (c.clients != a[0].clients
            or any(not np.array_equal(c.data[k], a[0].data[k])
                   for k in c.data))


def test_drift_applies_exactly_once(tiny):
    """No drift before round r; the corpus changes at r and only at r."""
    server = _build(tiny, k=1, name="fedentropy", drift=_drift(tiny, at=2))
    before = {k: np.array(v) for k, v in server.corpus.as_numpy().items()}
    sigs = []
    for _ in range(4):
        server.round()
        sigs.append({k: np.array(v)
                     for k, v in server.corpus.as_numpy().items()})
    # rounds 0,1 ran on the original corpus (drift applies at START of 2)
    for k in before:
        np.testing.assert_array_equal(sigs[0][k], before[k])
        np.testing.assert_array_equal(sigs[1][k], before[k])
        np.testing.assert_array_equal(sigs[2][k], sigs[3][k])
    assert any(not np.array_equal(sigs[2][k], before[k]) for k in before)
    assert server._drift == []


def test_assignment_partitions_cohort(tiny):
    """Every selected client lands in exactly one cluster, ids in [0, K)."""
    server = _build(tiny, k=3)
    for _ in range(3):
        rec = server.round()
        cids = rec["cluster"]
        assert len(cids) == len(rec["selected"])
        assert all(0 <= c < 3 for c in cids)
        members = [m for v in rec["clusters"].values()
                   for m in v["members"]]
        assert sorted(members) == sorted(rec["selected"])


def test_argmin_assign_k1_constant():
    scores = np.abs(np.random.default_rng(0).normal(size=(1, 7)))
    np.testing.assert_array_equal(fl.argmin_assign(scores), np.zeros(7))
    with pytest.raises(ValueError):
        fl.argmin_assign(np.zeros(3))


def test_model_bank_init_center0_exact(tiny):
    _, _, params = tiny
    bank = fl.ModelBank.init(params, 3, seed=0)
    assert bank.k == 3
    for a, b in zip(jax.tree.leaves(bank.center(0)),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # jittered centers differ from center 0 on inexact leaves
    assert _params_digest(bank.center(1)) != _params_digest(bank.center(0))
    # gather: row j is the assigned center
    g = bank.gather(np.asarray([2, 0, 1]))
    for leaf, s in zip(jax.tree.leaves(g), jax.tree.leaves(bank.stacked)):
        np.testing.assert_array_equal(np.asarray(leaf[1]),
                                      np.asarray(s[0]))


def test_registry_cluster_axis():
    assert "cluster" in fl.names.__globals__["KINDS"]
    assert sorted(fl.names("cluster")) == ["fesem", "ifca"]
    for comp in ("ifca", "ifca+maxent", "fesem"):
        recipe = fl.get("composition", comp)
        assert recipe.cluster in fl.names("cluster")
    assert fl.get("composition", "fedentropy").cluster is None


def test_perclstr_passthrough_without_cluster_key(tiny):
    """No ``cluster`` key in out -> the base weighted mean, exactly."""
    agg = fl.PerClusterAggregator()
    base = fl.WeightedAverageAggregator()
    rng = np.random.default_rng(0)
    out = {"params": {"w": jnp.asarray(rng.normal(size=(4, 3)))}}
    gp = {"w": jnp.asarray(rng.normal(size=(3,)))}
    sizes = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    np.testing.assert_array_equal(
        np.asarray(agg(gp, out, sizes, mask)["w"]),
        np.asarray(base(gp, out, sizes, mask)["w"]))


def test_perclstr_empty_cluster_keeps_center():
    """A cluster with no admitted member keeps its center unchanged."""
    agg = fl.PerClusterAggregator()
    rng = np.random.default_rng(1)
    stacked = {"w": jnp.asarray(rng.normal(size=(2, 3)))}
    out = {"params": {"w": jnp.asarray(rng.normal(size=(4, 3)))},
           "cluster": jnp.asarray([0, 0, 0, 0], jnp.int32)}
    sizes = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    new = agg(stacked, out, sizes, mask)
    # cluster 1 had no members at all: bitwise unchanged
    np.testing.assert_array_equal(np.asarray(new["w"][1]),
                                  np.asarray(stacked["w"][1]))
    # cluster 0 moved
    assert not np.array_equal(np.asarray(new["w"][0]),
                              np.asarray(stacked["w"][0]))
