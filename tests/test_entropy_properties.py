"""Property-based tests for core.entropy (paper Eq. 2-4).

Requires the ``hypothesis`` dev extra (``pip install -e .[dev]``); the
module skips cleanly when it is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.entropy import group_entropy, masked_soft_label_mean


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 16), st.integers(2, 32), st.integers(0, 10_000))
def test_property_entropy_bounds(m, c, seed):
    """0 <= H(weighted mean) <= log C for any soft labels/sizes/mask."""
    r = np.random.default_rng(seed)
    p = r.dirichlet(np.full(c, 0.2), size=m)
    sizes = r.uniform(1, 100, m)
    mask = (r.random(m) > 0.4).astype(np.float64)
    h = float(group_entropy(jnp.asarray(p, jnp.float32),
                            jnp.asarray(sizes, jnp.float32),
                            jnp.asarray(mask, jnp.float32)))
    assert -1e-5 <= h <= np.log(c) + 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(2, 16), st.integers(0, 10_000))
def test_property_mean_is_distribution(m, c, seed):
    r = np.random.default_rng(seed)
    p = r.dirichlet(np.full(c, 0.2), size=m)
    sizes = r.uniform(1, 100, m)
    mask = np.ones(m)
    mean = masked_soft_label_mean(
        jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(mask, jnp.float32))
    assert float(jnp.sum(mean)) == pytest.approx(1.0, abs=1e-4)
    assert float(jnp.min(mean)) >= 0.0
