"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: instantiate the REDUCED variant (<=2 layers/groups,
d_model<=256, <=4 experts), run one forward + one train step on CPU, assert
output shapes and no NaNs. Decode consistency: prefill + stepwise decode
reproduces the full-sequence forward logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.core.distributed import FedSpec, make_train_step
from repro.models.api import build_model
from repro.optim import sgd


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng, b=4, s=16)

    logits, aux = model.forward(params, batch)
    assert logits.shape == (4, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    fed = FedSpec(num_clients=2)
    opt = sgd(lr=0.01, momentum=0.5)
    step = jax.jit(make_train_step(model, opt, fed))
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert metrics["mask"].shape == (2,)
    assert 1 <= int(metrics["num_positive"]) <= 2
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_params),
                        jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch, rng):
    cfg = ARCHS[arch].reduced()
    if cfg.num_experts:   # avoid capacity-drop nondeterminism in the check
        cfg = cfg.replace(moe_capacity_factor=float(cfg.num_experts) /
                          cfg.experts_per_token)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s0, sd = 2, 12, 3
    batch = _batch(cfg, rng, b=b, s=s0 + sd)
    toks = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k != "tokens"}
    cache_extra = cfg.num_patches if cfg.family == "vlm" else 0

    full_logits, _ = model.forward(params, batch)
    logits, cache = model.prefill(
        params, {"tokens": toks[:, :s0], **extra},
        cache_len=s0 + sd + cache_extra)
    errs = [float(jnp.abs(logits[:, -1] - full_logits[:, s0 - 1]).max())]
    for t in range(s0, s0 + sd):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 2e-3, f"decode drift {max(errs)}"


def test_sliding_window_ring_buffer_decode(rng):
    """Windowed decode with a ring cache == full-cache windowed attention."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    w = 8
    b, steps = 1, 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, steps)),
                       jnp.int32)

    # reference: full cache, windowed attention
    full_logits, _ = model.forward(params, {"tokens": toks}, window=w)

    # ring cache of exactly window size
    cache = model.init_cache(b, w)
    outs = []
    for t in range(steps):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      window=w)
        outs.append(lg[:, 0])
    ring = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(full_logits),
                               atol=2e-3)


def test_moe_router_load_balance_aux(rng):
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, aux = model.forward(params, _batch(cfg, rng))
    # Switch aux loss >= 1 (equality iff perfectly balanced)
    assert float(aux) >= 0.99


def test_vlm_patch_conditioning_changes_logits(rng):
    cfg = ARCHS["internvl2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch, patches=batch["patches"] + 1.0)
    l2, _ = model.forward(params, batch2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_encdec_frames_conditioning(rng):
    cfg = ARCHS["whisper-large-v3"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    l1, _ = model.forward(params, batch)
    l2, _ = model.forward(params, dict(batch,
                                       frames=batch["frames"] * 2.0))
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_gradients_flow_everywhere(rng):
    """No dead parameters in the dense reduced model."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    def loss(p):
        return model.loss(p, batch)[0]
    grads = jax.grad(loss)(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert float(jnp.abs(g).max()) > 0, f"dead grad at {path}"
