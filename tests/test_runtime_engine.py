"""The ``repro.fl.runtime`` engines: golden-history equivalence of the
pipelined server (speculation off AND on), misspeculation fallback,
forced shard_map execution, the process-level compile cache, and the
engine registry plumbing."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import (
    RuntimeConfig, disable_process_cache, enable_process_cache,
    pad_to_multiple, process_cache,
)
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "seed_history.json")

# On a single device every engine compiles the identical program, so
# entropy is reproducible to the bit. Under a forced multi-device mesh
# (the XLA_FLAGS=--xla_force_host_platform_device_count CI job) the
# auto-sharded fan-out vmaps a different batch size than the recorder
# did, and CPU XLA is not bitwise-stable across batch sizes — verdict
# and selection ints stay exact, entropy floats carry a tolerance.
_SINGLE_DEVICE = len(jax.devices()) == 1
ENT_ATOL = 1e-9 if _SINGLE_DEVICE else 1e-6        # vs recorded goldens
ENT_ATOL_ENGINES = 1e-12 if _SINGLE_DEVICE else 1e-6   # engine vs engine


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def _build(tiny, name="fedentropy", runtime=None, engine="pipelined",
           **overrides):
    data, params = tiny
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20),
                    engine=engine, runtime=runtime, **overrides)


def _assert_matches_golden(history, golden):
    assert len(history) == len(golden)
    for g, w in zip(history, golden):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=ENT_ATOL)


# golden variant -> fl.build arguments (same mapping the legacy shim uses)
_VARIANTS = {
    "fedentropy": ("fedentropy", {}),
    "fedavg_uniform": ("fedavg", {}),
    "scaffold_fe": ("scaffold", {"selector": "pools", "judge": "maxent"}),
    "moon_nopools": ("moon", {"judge": "maxent"}),
}


@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_pipelined_speculation_off_matches_golden(tiny, variant):
    """ISSUE acceptance: PipelinedServer (speculation disabled) reproduces
    the recorded seed histories bit-for-bit, params digest included."""
    with open(GOLDEN) as f:
        golden = json.load(f)[variant]
    name, overrides = _VARIANTS[variant]
    server = _build(tiny, name, **overrides)
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)


def test_speculation_on_is_history_transparent(tiny):
    """With speculation ON the recorded history is still the oracle's,
    bit-for-bit vs golden — speculative draws happen on a throwaway
    selector copy adopted only when the device verdict is confirmed —
    and every record carries the spec_hit/redispatched flags."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedentropy"]
    server = _build(tiny, runtime=RuntimeConfig(speculate=True))
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)
    for rec in server.history:
        assert isinstance(rec["spec_hit"], bool)
        assert isinstance(rec["redispatched"], bool)
    # the float32 device judge agrees with the oracle on this corpus
    assert all(r["spec_hit"] for r in server.history)


def test_speculation_pallas_backend(tiny):
    """spec_backend="pallas" routes speculation through the class-tiled
    entropy_judge_sweep kernel (interpret mode on CPU)."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedentropy"]
    server = _build(tiny, runtime=RuntimeConfig(speculate=True,
                                                spec_backend="pallas"))
    for _ in range(3):
        server.round()
    _assert_matches_golden(server.history, golden["history"][:3])


class _WrongSpeculationJudge(fl.MaxEntropyJudge):
    """Oracle = real maxent; traced form always admits everyone, so every
    round with a rejection misspeculates."""

    def traced(self):
        return fl.PassThroughJudge().traced()


def test_misspeculation_falls_back_and_stays_correct(tiny):
    """A wrong device verdict must be discarded: history and params still
    match golden, rounds after a miss are flagged redispatched."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedentropy"]
    server = _build(tiny, runtime=RuntimeConfig(speculate=True),
                    judge=_WrongSpeculationJudge())
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)
    for prev, rec in zip(server.history, server.history[1:]):
        # golden rounds 0-2 reject a device -> speculation missed -> the
        # following round's compute was re-dispatched from the oracle
        assert rec["redispatched"] == (not prev["spec_hit"])
        assert prev["spec_hit"] == (not prev["negative"])


def test_speculation_with_orderless_judge_keeps_pool_population(tiny):
    """Judges whose JudgmentResult has removal_order=None (budgeted) must
    still re-file rejected devices into the pools on a speculative hit —
    regression test for the pool-drain bug (rejects filed nowhere)."""
    server = _build(tiny, judge=fl.BudgetedJudge(budget=2),
                    runtime=RuntimeConfig(speculate=True))
    for _ in range(3):
        rec = server.round()
        assert len(rec["positive"]) == 2 and len(rec["negative"]) == 2
    stats = server.selector.stats()
    # every device not held by the pending speculative selection is
    # back in a pool: nothing leaked
    assert stats["positive"] + stats["negative"] == 8 - 4


def test_forced_shard_map_matches_sequential(tiny):
    """shard=True runs the shard_map fan-out even on the 1-device CPU mesh;
    verdicts and params must match the sequential server exactly."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20))
    sharded = _build(tiny, runtime=RuntimeConfig(shard=True))
    for _ in range(3):
        seq.round()
        sharded.round()
    for g, w in zip(sharded.history, seq.history):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["entropy"] == pytest.approx(w["entropy"],
                                             abs=ENT_ATOL_ENGINES)
    for a, b in zip(jax.tree.leaves(sharded.global_params),
                    jax.tree.leaves(seq.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_client_mesh_from_production_mesh(tiny):
    """A launch.mesh production-style mesh reduces to its client rows
    (one slot per ("pod","data") row) and drives a sharded round."""
    from repro.fl.runtime import CLIENT_AXIS, PipelinedServer, \
        client_mesh_from
    from repro.launch.mesh import fl_clients_for, make_host_mesh
    mesh = make_host_mesh()
    cm = client_mesh_from(mesh)
    assert dict(cm.shape) == {CLIENT_AXIS: fl_clients_for(mesh)}
    data, params = tiny
    server = PipelinedServer(
        cnn.apply, params, data,
        fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
        selector=fl.PoolSelector(8), strategy=fl.FedAvgStrategy(
            LocalSpec(epochs=1, batch_size=20)),
        judge=fl.MaxEntropyJudge(), aggregator=fl.WeightedAverageAggregator(),
        runtime=RuntimeConfig(shard=True), mesh=mesh)
    rec = server.round()
    assert server.client_mesh().shape[CLIENT_AXIS] == fl_clients_for(mesh)
    assert len(rec["positive"]) + len(rec["negative"]) == 4


def test_pad_to_multiple():
    tree = {"x": jnp.arange(10).reshape(5, 2), "y": jnp.ones((5,))}
    padded = pad_to_multiple(tree, 4)
    assert padded["x"].shape == (8, 2) and padded["y"].shape == (8,)
    np.testing.assert_array_equal(np.asarray(padded["x"][:5]),
                                  np.arange(10).reshape(5, 2))
    np.testing.assert_array_equal(np.asarray(padded["x"][5:]),
                                  np.tile([[8, 9]], (3, 1)))
    same = pad_to_multiple(tree, 5)
    assert same["x"].shape == (5, 2)


# ------------------------------------------------ process compile cache

def test_process_cache_shares_compiles_across_servers(tiny):
    assert process_cache() is None        # default: per-server caches
    cache = enable_process_cache(maxsize=8)
    try:
        s1 = _build(tiny, engine=None)
        s2 = _build(tiny, engine=None)
        s1.round()
        assert cache.stats()["misses"] >= 1
        s2.round()
        assert cache.stats()["hits"] >= 1          # s2 reused s1's program
        assert len(s1._jit_cache) == 0             # per-server LRUs idle
        assert len(s2._jit_cache) == 0
    finally:
        disable_process_cache()
    assert process_cache() is None


def test_process_cache_rebound_trims():
    cache = enable_process_cache(maxsize=4)
    try:
        for i in range(4):
            cache.get(("k", i), lambda i=i: i)
        assert len(cache) == 4
        cache2 = enable_process_cache(maxsize=2)
        assert cache2 is cache and len(cache) == 2
    finally:
        disable_process_cache()


# ------------------------------------------------------ registry plumbing

def test_engine_registry(tiny):
    from repro.fl.runtime import PipelinedServer, SequentialEngine
    assert fl.get("engine", "pipelined") is PipelinedServer
    assert fl.get("engine", "sequential") is SequentialEngine
    # unknown engine names fail in build() with the registered names listed
    # (not a KeyError deep in construction) — see tests/test_async_engine.py
    # for the engine/runtime mismatch matrix
    with pytest.raises(ValueError, match="unknown engine 'warp'.*async.*"
                                         "pipelined.*sequential"):
        _build(tiny, engine="warp")
    assert isinstance(_build(tiny), PipelinedServer)
    assert isinstance(_build(tiny, engine=None), fl.Server)
    # a RuntimeConfig without an engine routes to the engine it configures
    # rather than being silently ignored by the sequential driver
    s = _build(tiny, engine=None, runtime=RuntimeConfig(speculate=True))
    assert isinstance(s, PipelinedServer)
    assert s.runtime.speculate
    s2 = _build(tiny, engine="sequential", runtime=RuntimeConfig())
    assert isinstance(s2, SequentialEngine)


# -------------------------------------------- launch satellite: dryrun fix

def test_cost_analysis_dict_shapes():
    """jax 0.4.3x returns a per-device LIST from cost_analysis(); older
    stacks one dict; both (and None) must normalize."""
    from repro.launch.hlo_analysis import cost_analysis_dict
    assert cost_analysis_dict(None) == {}
    assert cost_analysis_dict([]) == {}
    assert cost_analysis_dict({"flops": 1.0}) == {"flops": 1.0}
    assert cost_analysis_dict([{"flops": 2.0}, {"flops": 2.0}]) == \
        {"flops": 2.0}
    got = cost_analysis_dict(jax.jit(lambda x: x * 2).lower(
        jnp.ones((4,))).compile().cost_analysis())
    assert isinstance(got, dict)
