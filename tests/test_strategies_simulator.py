"""Client strategies (FedAvg/FedProx/SCAFFOLD/Moon) + the vmapped Alg. 2
simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulator import FedEntropyTrainer, FLConfig
from repro.core.strategies import LocalSpec, client_update, cross_entropy
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn


@pytest.fixture(scope="module")
def tiny_fl():
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params, (jnp.asarray(xte), jnp.asarray(yte))


def _one_client(data, i):
    return {k: jnp.asarray(v[i]) for k, v in data.items()}


def test_client_update_reduces_local_loss(tiny_fl):
    data, params, _ = tiny_fl
    d = _one_client(data, 0)
    spec = LocalSpec(epochs=3, batch_size=20)
    out = client_update(cnn.apply, params, d, spec)
    logits0, _ = cnn.apply(params, d["x"])
    logits1, _ = cnn.apply(out["params"], d["x"])
    l0 = float(cross_entropy(logits0, d["y"], d["w"]))
    l1 = float(cross_entropy(logits1, d["y"], d["w"]))
    assert l1 < l0


def test_soft_label_reflects_single_label_bias(tiny_fl):
    """Case-1 clients hold one label; after local training the soft label
    must put most mass on it (paper Eq. 2's purpose)."""
    data, params, _ = tiny_fl
    d = _one_client(data, 0)
    label = int(d["y"][0])
    out = client_update(cnn.apply, params, d,
                        LocalSpec(epochs=5, batch_size=20, lr=0.05))
    soft = np.asarray(out["soft_label"])
    assert soft.argmax() == label
    assert soft.sum() == pytest.approx(1.0, abs=1e-4)


def test_fedprox_stays_closer_to_global(tiny_fl):
    data, params, _ = tiny_fl
    d = _one_client(data, 1)

    def dist(p):
        return float(sum(jnp.sum((a - b) ** 2) for a, b in zip(
            jax.tree.leaves(p), jax.tree.leaves(params))))

    out_avg = client_update(cnn.apply, params, d,
                            LocalSpec(strategy="fedavg", epochs=3,
                                      batch_size=20, lr=0.05))
    out_prox = client_update(cnn.apply, params, d,
                             LocalSpec(strategy="fedprox", epochs=3,
                                       batch_size=20, lr=0.05, prox_mu=1.0))
    assert dist(out_prox["params"]) < dist(out_avg["params"])


def test_scaffold_state_updates(tiny_fl):
    data, params, _ = tiny_fl
    d = _one_client(data, 2)
    z = jax.tree.map(jnp.zeros_like, params)
    out = client_update(cnn.apply, params, d,
                        LocalSpec(strategy="scaffold", epochs=2,
                                  batch_size=20),
                        c_local=z, c_global=z)
    assert "c_local" in out and "c_delta" in out
    nonzero = any(float(jnp.abs(x).max()) > 0
                  for x in jax.tree.leaves(out["c_delta"]))
    assert nonzero


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "scaffold",
                                      "moon"])
def test_trainer_round_all_strategies(tiny_fl, strategy):
    data, params, _ = tiny_fl
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5, seed=0),
        LocalSpec(strategy=strategy, epochs=1, batch_size=20))
    rec = tr.round()
    assert len(rec["selected"]) == 4
    assert len(rec["positive"]) + len(rec["negative"]) == 4
    assert len(rec["positive"]) >= 1
    assert rec["comm"]["savings_fraction"] >= 0.0 or strategy == "scaffold"


def test_trainer_judgment_ablation(tiny_fl):
    """use_judgment=False keeps every selected device positive."""
    data, params, _ = tiny_fl
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5, use_judgment=False,
                 seed=0),
        LocalSpec(epochs=1, batch_size=20))
    rec = tr.round()
    assert len(rec["positive"]) == 4 and not rec["negative"]


def test_trainer_improves_accuracy(tiny_fl):
    data, params, test = tiny_fl
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5, seed=0),
        LocalSpec(epochs=2, batch_size=20, lr=0.02))
    acc0 = tr.evaluate(*test)["accuracy"]
    for _ in range(8):
        tr.round()
    acc1 = tr.evaluate(*test)["accuracy"]
    assert acc1 > max(acc0, 0.5)
