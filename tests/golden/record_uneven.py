"""Record tests/golden/uneven_history.json from the sequential ``Server``.

Paper-scale reference for the uneven-mesh (padded-shard) layout: N=100
clients — not divisible by any realistic accelerator count — across the
fedentropy, fedcat+maxent, and fedentropy+queue compositions. Run from
the repo root after any INTENTIONAL change to round semantics (never to
paper over a regression):

    PYTHONPATH=src python tests/golden/record_uneven.py

Recorded from the sequential engine on the default single-device CPU so
the padded/sharded/speculative engines on any mesh size are all held to
the same reference (tests/test_uneven_shard.py compares the integer
verdict history bit-for-bit; entropy floats cross compiled-program
shapes, so they carry a tolerance there).
"""
import json
import os

import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 3
PAPER_N, CLASSES = 100, 10
VARIANTS = {"fedentropy": "fedentropy", "fedcat_maxent": "fedcat+maxent",
            "fedentropy_queue": "fedentropy+queue"}
OUT = os.path.join(os.path.dirname(__file__), "uneven_history.json")


def paper_corpus():
    """Mirrors tests/test_uneven_shard.py's ``paper`` fixture exactly."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=CLASSES, train_per_class=2 * PAPER_N, test_per_class=10,
        hw=16, noise=0.9, seed=0)
    parts = partition("case1", ytr, PAPER_N, CLASSES, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=10)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16,
                      num_classes=CLASSES)
    return data, params


def digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def main() -> None:
    data, params = paper_corpus()
    blob = {}
    for key, comp in VARIANTS.items():
        server = fl.build(comp, cnn.apply, params, data,
                          fl.ServerConfig(num_clients=PAPER_N,
                                          participation=0.1, seed=0,
                                          group_size=2),
                          LocalSpec(epochs=1, batch_size=10))
        records = []
        for _ in range(ROUNDS):
            rec = server.round()
            records.append({
                "round": rec["round"], "selected": rec["selected"],
                "positive": rec["positive"], "negative": rec["negative"],
                "entropy": repr(rec["entropy"]),
                "total_bytes": rec["comm"]["total_bytes"],
            })
        blob[key] = {"history": records,
                     "params_digest": repr(digest(server.global_params))}
    with open(OUT, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
