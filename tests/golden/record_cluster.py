"""Record tests/golden/cluster_history.json from the sequential ``Server``.

The clustered-rounds reference: the tiny 8-client fixture running the
``ifca+maxent`` composition with a K=3 ModelBank and one drift event at
round 2 (half the clients re-partitioned, seeded). Run from the repo
root after any INTENTIONAL change to clustered round semantics (never to
paper over a regression):

    PYTHONPATH=src python tests/golden/record_cluster.py

Recorded from the sequential engine on the default single-device CPU;
tests/test_cluster_engine.py holds the sequential AND pipelined engines
(speculation off and on) to this one reference bit-for-bit, and the
forced-8-device CI job re-runs the comparison across the mesh.
"""
import json
import os

import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import drift_schedule, partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 6
NUM_CLUSTERS = 3
DRIFT_ROUND = 2
OUT = os.path.join(os.path.dirname(__file__), "cluster_history.json")


def tiny_corpus():
    """Mirrors tests/test_fl_api.py's ``tiny`` fixture exactly."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return (xtr, ytr), data, params


def digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def main() -> None:
    (xtr, ytr), data, params = tiny_corpus()
    drift = drift_schedule(
        xtr, ytr, 8, 4, at=DRIFT_ROUND, seed=0,
        samples_per_client=int(data["y"].shape[1]))
    server = fl.build(
        "ifca+maxent", cnn.apply, params, data,
        fl.ServerConfig(num_clients=8, participation=0.5, seed=0,
                        num_clusters=NUM_CLUSTERS),
        LocalSpec(epochs=1, batch_size=20), drift=drift)
    records = []
    for _ in range(ROUNDS):
        rec = server.round()
        records.append({
            "round": rec["round"], "selected": rec["selected"],
            "positive": rec["positive"], "negative": rec["negative"],
            "entropy": repr(rec["entropy"]),
            "total_bytes": rec["comm"]["total_bytes"],
            "cluster": rec["cluster"],
            "clusters": {
                k: {"members": v["members"], "positive": v["positive"],
                    "negative": v["negative"], "entropy": repr(v["entropy"])}
                for k, v in rec["clusters"].items()},
            "drift": rec.get("drift"),
        })
    blob = {"ifca_maxent_k3_drift": {
        "num_clusters": NUM_CLUSTERS, "drift_round": DRIFT_ROUND,
        "history": records,
        "params_digest": repr(digest(server.bank.stacked))}}
    with open(OUT, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
