"""Record tests/golden/fedcat_history.json from the sequential ``Server``.

Run from the repo root after any INTENTIONAL change to fedcat round
semantics (never to paper over a regression):

    PYTHONPATH=src python tests/golden/record_fedcat.py

The fixture mirrors tests/test_fedcat.py's ``tiny`` exactly; histories are
recorded from the sequential engine so the pipelined/sharded/speculative
engines are all held to the same reference.
"""
import json
import os

import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 5
VARIANTS = {"fedcat": "fedcat", "fedcat_maxent": "fedcat+maxent"}
OUT = os.path.join(os.path.dirname(__file__), "fedcat_history.json")


def tiny():
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def main() -> None:
    data, params = tiny()
    blob = {}
    for key, comp in VARIANTS.items():
        server = fl.build(comp, cnn.apply, params, data,
                          fl.ServerConfig(num_clients=8, participation=0.5,
                                          seed=0, group_size=2),
                          LocalSpec(epochs=1, batch_size=20))
        records = []
        for _ in range(ROUNDS):
            rec = server.round()
            records.append({
                "round": rec["round"], "selected": rec["selected"],
                "positive": rec["positive"], "negative": rec["negative"],
                "entropy": repr(rec["entropy"]),
                "total_bytes": rec["comm"]["total_bytes"],
                "groups": server.selector.last_groups,
            })
        blob[key] = {"history": records,
                     "params_digest": repr(digest(server.global_params))}
    with open(OUT, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
