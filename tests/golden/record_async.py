"""Record tests/golden/async_history.json from ``AsyncBufferedServer``.

Two reduction variants (buffer K = |cohort|, zero-latency clock, damping
off — contractually bit-for-bit equal to the sequential ``Server``, i.e.
to the matching variants of seed_history.json) plus one straggler-clock
variant that pins the async-specific record fields (flush virtual time,
staleness distribution, arrival sequence ids). Run from the repo root
after any INTENTIONAL change to flush semantics (never to paper over a
regression):

    PYTHONPATH=src python tests/golden/record_async.py

Recorded on the default single-device CPU; tests/test_async_engine.py
compares the integer verdict/stream history bit-for-bit everywhere and
gives entropy floats a tolerance under forced multi-device meshes (same
policy as the other goldens).
"""
import json
import os

import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 3
OUT = os.path.join(os.path.dirname(__file__), "async_history.json")

# variant -> (composition, AsyncConfig)
VARIANTS = {
    "fedentropy": ("fedentropy", fl.AsyncConfig()),
    "fedavg_uniform": ("fedavg", fl.AsyncConfig()),
    "fedentropy_straggler": ("fedentropy", fl.AsyncConfig(
        clock="straggler", latency_scale=1.0, straggler_frac=0.25,
        straggler_factor=8.0, staleness_alpha=0.5, seed=0)),
}


def tiny_corpus():
    """Mirrors tests/test_runtime_engine.py's ``tiny`` fixture exactly."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def main() -> None:
    data, params = tiny_corpus()
    blob = {}
    for key, (comp, runtime) in VARIANTS.items():
        server = fl.build(comp, cnn.apply, params, data,
                          fl.ServerConfig(num_clients=8, participation=0.5,
                                          seed=0),
                          LocalSpec(epochs=1, batch_size=20),
                          engine="async", runtime=runtime)
        records = []
        for _ in range(ROUNDS):
            rec = server.round()
            records.append({
                "round": rec["round"], "selected": rec["selected"],
                "positive": rec["positive"], "negative": rec["negative"],
                "entropy": repr(rec["entropy"]),
                "total_bytes": rec["comm"]["total_bytes"],
                "flush_time": repr(rec["flush_time"]),
                "staleness": rec["staleness"],
                "seq": rec["seq"],
                "admitted_seq": rec["admitted_seq"],
            })
        blob[key] = {"history": records,
                     "params_digest": repr(digest(server.global_params))}
    with open(OUT, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
