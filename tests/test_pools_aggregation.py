"""Device pools (Alg. 2 l.4-8/22) and weighted aggregation (l.21)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import aggregate, comm_bytes
from repro.core.pools import DevicePools


def test_pools_start_all_positive():
    p = DevicePools(20)
    assert p.stats() == {"positive": 20, "negative": 0}


def test_select_removes_and_update_refiles():
    p = DevicePools(10, seed=0)
    sel = p.select(4)
    assert len(sel) == 4
    assert p.stats()["positive"] == 6
    p.update(sel[:1], sel[1:])
    assert p.stats() == {"positive": 7, "negative": 3}
    assert set(sel[1:]) <= p.negative


def test_select_overflows_to_other_pool():
    p = DevicePools(10, eps=0.0, seed=1)   # always try negative pool first
    sel = p.select(5)                       # negative pool empty -> positive
    assert len(sel) == 5


def test_eps_greedy_distribution():
    """With eps=0.8 the positive pool is preferred ~80% of the time."""
    hits = 0
    trials = 300
    for seed in range(trials):
        p = DevicePools(10, eps=0.8, seed=seed)
        p.positive = set(range(5))
        p.negative = set(range(5, 10))
        sel = p.select(2)
        if set(sel) <= set(range(5)):
            hits += 1
    assert 0.7 < hits / trials < 0.9


def test_aggregate_matches_paper_formula(rng):
    m = 5
    stacked = {"w": jnp.asarray(rng.normal(size=(m, 3, 4)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(m, 4)), jnp.float32)}
    sizes = jnp.asarray([10, 20, 30, 40, 50], jnp.float32)
    mask = jnp.asarray([1, 0, 1, 0, 1], jnp.float32)
    agg = aggregate(stacked, sizes, mask)
    w = np.asarray(sizes) * np.asarray(mask)
    ref = (np.asarray(stacked["w"]) * w[:, None, None]).sum(0) / w.sum()
    np.testing.assert_allclose(np.asarray(agg["w"]), ref, rtol=1e-5)


def test_aggregate_all_positive_is_weighted_fedavg(rng):
    m = 4
    stacked = {"w": jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)}
    sizes = jnp.ones((m,), jnp.float32)
    agg = aggregate(stacked, sizes, jnp.ones((m,)))
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.asarray(stacked["w"]).mean(0), rtol=1e-5)


def test_comm_bytes_savings():
    """Dropping negatives must save bytes; soft labels are tiny."""
    tmpl = {"w": jnp.zeros((1000, 1000), jnp.float32)}   # 4 MB model
    full = comm_bytes(tmpl, num_selected=10, num_positive=10,
                      num_classes=10)
    half = comm_bytes(tmpl, num_selected=10, num_positive=5,
                      num_classes=10)
    assert half["total_bytes"] < full["total_bytes"]
    assert half["savings_fraction"] == pytest.approx(0.5, abs=0.01)
    assert full["soft_label_bytes"] < 0.001 * full["model_bytes"]


def test_comm_bytes_scaffold_doubles():
    tmpl = {"w": jnp.zeros((100, 100), jnp.float32)}
    a = comm_bytes(tmpl, 10, 10, 10, control_variate=False)
    b = comm_bytes(tmpl, 10, 10, 10, control_variate=True)
    assert b["model_bytes"] == 2 * a["model_bytes"]
