"""Hypothesis properties of the async buffered engine (ISSUE satellites):

(a) staleness weights are monotone non-increasing in τ and reduce to
    uniform at α = 0;
(b) every admitted update appears in exactly one flush, over random
    seeds and clocks;
(c) the K=|cohort| zero-staleness reduction to the sequential ``Server``
    holds across seeds, not just the recorded seed.

Engine-level properties (b)/(c) train a real (tiny) CNN per example, so
example counts stay small; the deterministic fixed-seed twins live in
tests/test_async_engine.py and run everywhere hypothesis is absent
(locally the tier-1 suite skips this module; CI's dev extra installs
hypothesis and runs it, including on the forced 8-device mesh).
"""
import functools

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.fl as fl  # noqa: E402
from repro.core.strategies import LocalSpec  # noqa: E402
from repro.data.partition import partition, stack_clients  # noqa: E402
from repro.data.synthetic import make_image_dataset  # noqa: E402
from repro.fl.runtime import AsyncConfig, staleness_weights  # noqa: E402
from repro.models import cnn  # noqa: E402


@functools.lru_cache(maxsize=1)
def _tiny():
    """Memoized module corpus (a plain function, not a pytest fixture, so
    @given draws never interact with fixture scoping)."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _build(seed, engine=None, runtime=None):
    data, params = _tiny()
    return fl.build("fedentropy", cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=seed),
                    LocalSpec(epochs=1, batch_size=20),
                    engine=engine, runtime=runtime)


# ------------------------------------------------- (a) staleness weights

@given(tau=st.lists(st.integers(min_value=0, max_value=50), min_size=1,
                    max_size=16),
       alpha=st.floats(min_value=0.0, max_value=8.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_staleness_weights_monotone_and_uniform_at_zero(tau, alpha):
    order = np.sort(np.asarray(tau))
    w = staleness_weights(order, alpha)
    assert np.all(w > 0) and np.all(w <= 1.0)
    assert np.all(np.diff(w) <= 0)               # monotone non-increasing
    np.testing.assert_allclose(staleness_weights(order, 0.0), 1.0)
    # strictly decreasing where tau strictly increases and alpha > 0
    if alpha > 0:
        inc = np.diff(order) > 0
        assert np.all(np.diff(w)[inc] < 0)


# ------------------------------------- (b) flushes partition the stream

@given(seed=st.integers(min_value=0, max_value=10_000),
       clock=st.sampled_from(["uniform", "straggler"]),
       buffer_size=st.sampled_from([0, 2, 3]))
@settings(max_examples=5, deadline=None)
def test_each_admitted_update_in_exactly_one_flush(seed, clock,
                                                   buffer_size):
    server = _build(seed=seed, engine="async", runtime=AsyncConfig(
        buffer_size=buffer_size, clock=clock, latency_scale=1.0,
        straggler_frac=0.25, straggler_factor=8.0, staleness_alpha=0.5,
        seed=seed))
    recs = [server.round() for _ in range(3)]
    seen: set = set()
    admitted_total = 0
    for rec in recs:
        batch = set(rec["seq"])
        assert len(batch) == len(rec["seq"])        # no double-screening
        assert not (batch & seen)                   # exactly-one-flush
        assert set(rec["admitted_seq"]) <= batch
        assert len(rec["admitted_seq"]) == len(rec["positive"])
        admitted_total += len(rec["admitted_seq"])
        seen |= batch
    assert admitted_total == sum(len(r["positive"]) for r in recs)


# ----------------------------------------- (c) reduction across seeds

@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=5, deadline=None)
def test_zero_staleness_reduction_across_seeds(seed):
    seq = _build(seed=seed)
    asy = _build(seed=seed, engine="async")
    for _ in range(2):
        a, b = seq.round(), asy.round()
        assert a["selected"] == b["selected"]
        assert a["positive"] == b["positive"]
        assert a["negative"] == b["negative"]
        assert a["comm"] == b["comm"]
        assert b["staleness"] == [0] * len(b["selected"])
    for x, y in zip(jax.tree.leaves(seq.global_params),
                    jax.tree.leaves(asy.global_params)):
        if len(jax.devices()) == 1:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)
