"""Property-based tests for FedCAT grouping and concatenation aggregation.

Requires the ``hypothesis`` dev extra (``pip install -e .[dev]``); the
module skips cleanly when it is absent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.fl as fl  # noqa: E402
from repro.core.pools import (  # noqa: E402
    greedy_entropy_groups, hist_entropy, label_histograms,
)


def _hists(n, c, seed, concentration=0.3):
    r = np.random.default_rng(seed)
    return r.dirichlet(np.full(c, concentration), size=n) * \
        r.integers(20, 400, (n, 1)).astype(np.float64)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16), st.integers(1, 6), st.integers(2, 8),
       st.integers(0, 100_000))
def test_property_groups_partition_exactly_once(n, c, k, seed):
    """Every device appears in exactly one group, groups never exceed the
    requested size, and only the last group may be smaller."""
    groups = greedy_entropy_groups(_hists(n, c, seed), k)
    flat = [i for g in groups for i in g]
    assert sorted(flat) == list(range(n))
    assert all(1 <= len(g) <= k for g in groups)
    assert all(len(g) == k for g in groups[:-1])


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(2, 6), st.integers(2, 4),
       st.integers(0, 100_000))
def test_property_grouping_deterministic_in_seed(n, c, k, seed):
    """Two CatGroupers with the same seed and the same bound corpus draw
    the same selections AND the same ordered groups, round after round —
    the invariant that makes speculative group dispatch replayable."""
    r = np.random.default_rng(seed)
    y = r.integers(0, c, (n, 12))
    w = (r.random((n, 12)) > 0.2).astype(np.float64)
    config = fl.ServerConfig(num_clients=n, participation=0.5, seed=seed,
                             group_size=k)
    a = fl.CatGrouper.from_config(config, None)
    b = fl.CatGrouper.from_config(config, None)
    a.bind_data({"y": y, "w": w})
    b.bind_data({"y": y, "w": w})
    for _ in range(3):
        sa, sb = a.select(max(2, n // 2)), b.select(max(2, n // 2))
        assert sa == sb
        assert a.last_groups == b.last_groups
        flat = sorted(i for g in a.last_groups for i in g)
        assert flat == list(range(len(sa)))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10), st.integers(2, 5), st.integers(0, 100_000))
def test_property_group_size_1_reduces_to_weighted_average(n, d, seed):
    """DeviceConcatAggregator over singleton chains IS the plain
    size-weighted average — same arrays, bit for bit."""
    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.normal(size=(n, d)), jnp.float32),
              "b": jnp.asarray(r.normal(size=(n,)), jnp.float32)}
    sizes = jnp.asarray(r.integers(1, 100, n), jnp.float32)
    mask = jnp.asarray(r.integers(0, 2, n), jnp.float32)
    gp = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}
    out = {"params": params,
           "group_id": jnp.arange(n, dtype=jnp.int32),
           "chain_pos": jnp.zeros(n, jnp.int32)}
    cat = fl.DeviceConcatAggregator()(gp, out, sizes, mask)
    avg = fl.WeightedAverageAggregator()(gp, dict(params=params), sizes,
                                         mask)
    if float(jnp.sum(sizes * mask)) > 0:
        for a, b in zip(jax.tree.leaves(cat),
                        jax.tree.leaves(avg)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:   # all rejected: fedcat keeps the global model, fedavg zeroes it
        for a, b in zip(jax.tree.leaves(cat),
                        jax.tree.leaves(gp)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(2, 8), st.integers(0, 100_000))
def test_property_greedy_groups_entropy_at_least_singletons(n, c, seed):
    """The greedy pooled-histogram entropy of every full group is at least
    the entropy of its own most-skewed member (adding devices with other
    labels cannot lower the pooled entropy below the seed's)."""
    hists = _hists(n, c, seed)
    for g in greedy_entropy_groups(hists, 3):
        pooled = hist_entropy(np.sum(hists[g], axis=0))
        assert pooled >= min(hist_entropy(hists[i]) for i in g) - 1e-9


def test_label_histograms_respects_weights():
    y = np.array([[0, 1, 1], [2, 2, 0]])
    w = np.array([[1.0, 0.0, 1.0], [1.0, 1.0, 0.0]])
    h = label_histograms(y, w, num_classes=3)
    np.testing.assert_array_equal(h, [[1, 1, 0], [0, 0, 2]])
