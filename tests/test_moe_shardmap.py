"""MoE dispatch paths: pjit reference vs shard_map expert-parallel path.

On the single CPU device a (1, 1) ("data","model") mesh makes the
shard_map path exercise its full code (all_to_all degenerates to identity)
so we can assert it matches the pjit path numerically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe as moe_mod
from repro.sharding.ctx import use_mesh


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced().replace(
        moe_capacity_factor=2.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    return cfg, p, x


def test_shard_map_matches_pjit(setup):
    cfg, p, x = setup
    out_ref, aux_ref = moe_mod.moe_block_pjit(cfg, p, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out_sm, aux_sm = moe_mod.moe_block_shard_map(cfg, p, x, mesh)
    np.testing.assert_allclose(np.asarray(out_sm), np.asarray(out_ref),
                               atol=2e-5)
    assert float(aux_sm) == pytest.approx(float(aux_ref), rel=1e-4)


def test_moe_block_dispatches_by_context(setup):
    cfg, p, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        out_ctx, _ = moe_mod.moe_block(cfg, p, x)
    out_ref, _ = moe_mod.moe_block_pjit(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_ctx), np.asarray(out_ref),
                               atol=2e-5)


def test_shard_map_grads_flow(setup):
    cfg, p, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss(params):
        out, aux = moe_mod.moe_block_shard_map(cfg, params, x, mesh)
        return jnp.sum(out ** 2) + 0.01 * aux

    grads = jax.grad(loss)(p)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g))), path
    # experts that received tokens must have nonzero grads
    assert float(jnp.abs(grads["w_in"]).max()) > 0


def test_capacity_drops_are_bounded(setup):
    """With cf=E/k nothing drops; with tiny cf most token-slots drop but
    output stays finite."""
    cfg, p, x = setup
    tiny = cfg.replace(moe_capacity_factor=0.01)
    out, _ = moe_mod.moe_block_pjit(tiny, p, x)
    assert np.all(np.isfinite(np.asarray(out)))
