"""Untested ``fl/runtime`` edges: chain/mesh padding isolation and
``ProcessCompileCache`` eviction + hit accounting under a sweep of
distinct ``RuntimeConfig``s."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import (
    RuntimeConfig, disable_process_cache, enable_process_cache,
    make_client_mesh, make_sharded_client_fn, pad_to_multiple,
    process_cache,
)
from repro.models import cnn


@pytest.fixture(scope="module")
def tiny():
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


class _FixedGroups:
    """Selector stub exposing a fixed ``last_groups`` assignment."""

    def __init__(self, groups):
        self.last_groups = groups


def _chain(tiny, groups, sel):
    """Run one chain dispatch over ``sel`` with a fixed group layout."""
    data, params = tiny
    strat = fl.CatChainStrategy(LocalSpec(epochs=1, batch_size=20))
    idx = np.asarray(sel)
    cohort = {k: v[idx] for k, v in data.items()}
    gdata, aux = strat.prepare_round(cohort, _FixedGroups(groups))
    fn = jax.jit(strat.make_client_fn(cnn.apply))
    out = fn(params, gdata, None, None, None, aux["valid"])
    return strat.finish_round(out, aux), gdata, aux


# --------------------------------------------------- chain padding edges

def test_ragged_group_padding_does_not_leak_into_chain(tiny):
    """A ragged group is padded to the longest chain length with valid=0
    stages. The pad must be inert: swapping WHAT data sits in the padded
    slot cannot change any real device's output by a single bit, and the
    padded chain agrees with the unpadded 2-chain numerically."""
    data, params = tiny
    strat = fl.CatChainStrategy(LocalSpec(epochs=1, batch_size=20))
    sel = np.asarray([0, 1, 2, 3, 4])
    cohort = {k: v[sel] for k, v in data.items()}
    gdata, aux = strat.prepare_round(cohort, _FixedGroups([[0, 1, 2],
                                                           [3, 4]]))
    fn = jax.jit(strat.make_client_fn(cnn.apply))
    ref = strat.finish_round(fn(params, gdata, None, None, None,
                                aux["valid"]), aux)

    # poison the padded slot (group 1, stage 2) with a different device
    poisoned = {k: jnp.asarray(v).at[1, 2].set(v[0, 0])
                for k, v in gdata.items()}
    out = strat.finish_round(fn(params, poisoned, None, None, None,
                                aux["valid"]), aux)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the ragged chain agrees with the same chain run unpadded
    alone, _, _ = _chain(tiny, [[0, 1]], [3, 4])
    for a, b in zip(jax.tree.leaves(
            jax.tree.map(lambda x: x[3:5], ref["params"])),
            jax.tree.leaves(alone["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_mesh_padding_repeats_whole_groups_and_is_dropped(tiny):
    """Uneven group counts vs mesh size: the sharded wrapper pads the
    GROUP axis by repeating the last group; outputs of the padded replica
    must be sliced off and the real chains unchanged."""
    data, params = tiny
    strat = fl.CatChainStrategy(LocalSpec(epochs=1, batch_size=20))
    sel = [0, 1, 2, 3, 4, 5]
    groups = [[0, 1], [2, 3], [4, 5]]
    idx = np.asarray(sel)
    cohort = {k: v[idx] for k, v in data.items()}
    gdata, aux = strat.prepare_round(cohort, _FixedGroups(groups))

    ref = jax.jit(strat.make_client_fn(cnn.apply))(
        params, gdata, None, None, None, aux["valid"])

    # 3 groups on a 1-device mesh is already even; force the uneven case
    # by invoking the wrapper's own padding at a multiple of 2
    padded_gdata = pad_to_multiple(gdata, 2)
    padded_valid = pad_to_multiple(aux["valid"], 2)
    assert padded_gdata["x"].shape[0] == 4        # 3 -> 4 groups
    fn = jax.jit(strat.make_client_fn(cnn.apply))
    out = fn(params, padded_gdata, None, None, None, padded_valid)
    sliced = jax.tree.map(lambda x: x[:3], out)
    for a, b in zip(jax.tree.leaves(sliced), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # the padded replica is inert: poisoning it cannot move a real bit
    poisoned = {k: jnp.asarray(v).at[3].set(v[0])
                for k, v in padded_gdata.items()}
    out2 = fn(params, poisoned, None, None, None, padded_valid)
    for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[:3], out2)),
                    jax.tree.leaves(sliced)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and through the real sharded wrapper on the CPU mesh
    mesh = make_client_mesh(jax.devices()[:1])
    sharded = make_sharded_client_fn(
        cnn.apply, strat.spec, strat.client_in_axes(), mesh,
        inner=strat.make_client_fn(cnn.apply))
    out2 = sharded(params, gdata, None, None, None, aux["valid"])
    for a, b in zip(jax.tree.leaves(out2), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_uneven_cohort_vs_mesh_padding_unchanged_for_vmap_path(tiny):
    """The device-level (non-chain) sharded path still pads client rows
    and slices them off — regression guard for the *rest* signature."""
    data, params = tiny
    strat = fl.FedAvgStrategy(LocalSpec(epochs=1, batch_size=20))
    mesh = make_client_mesh(jax.devices()[:1])
    fn = make_sharded_client_fn(cnn.apply, strat.spec,
                                strat.client_in_axes(), mesh)
    cohort = {k: v[np.asarray([0, 1, 2])] for k, v in data.items()}
    out = fn(params, cohort, None, None, None)
    assert out["soft_label"].shape[0] == 3


def test_sharded_client_fn_pads_inside_the_traced_program(tiny):
    """Cohort pad-to-mesh and slice-back are traced, not eager: the
    wrapper IS the jitted program (one dispatch per round, the
    repeat/concatenate fuse into it), and repeated uneven cohorts reuse
    a single compiled entry per shape. Uses the FULL device mesh so the
    multidevice CI job (8 forced devices, cohort 3 -> pad 8) traces a
    real pad; on one device the pad degenerates to identity."""
    data, params = tiny
    strat = fl.FedAvgStrategy(LocalSpec(epochs=1, batch_size=20))
    mesh = make_client_mesh()
    fn = make_sharded_client_fn(cnn.apply, strat.spec,
                                strat.client_in_axes(), mesh)
    assert hasattr(fn, "lower")                   # a jit stage, not a closure
    cohort = {k: v[np.asarray([0, 1, 2])] for k, v in data.items()}
    for _ in range(2):
        out = fn(params, cohort, None, None, None)
        assert out["soft_label"].shape[0] == 3
    cache_size = getattr(fn, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 1                  # one program, reused


# ------------------------------------------- process cache under a sweep

def _build(tiny, runtime, name="fedentropy"):
    data, params = tiny
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20),
                    engine="pipelined", runtime=runtime)


def test_process_cache_sweep_evicts_and_counts(tiny):
    """Distinct RuntimeConfigs compile distinct sharded programs: a sweep
    wider than ``maxsize`` must evict LRU-first while the hit/miss
    counters stay exact."""
    assert process_cache() is None
    cache = enable_process_cache(maxsize=2)
    try:
        cfgs = [RuntimeConfig(shard=True, donate_data=True),
                RuntimeConfig(shard=True, donate_data=False),
                RuntimeConfig(shard=False)]
        for rt in cfgs:                       # 3 distinct keys, bound 2
            _build(tiny, rt).round()
        assert cache.stats() == {"hits": 0, "misses": 3, "entries": 2,
                                 "maxsize": 2}
        # most recent config is resident -> hit; the evicted one re-misses
        _build(tiny, cfgs[2]).round()
        assert cache.stats()["hits"] == 1
        _build(tiny, cfgs[0]).round()
        st = cache.stats()
        assert st["misses"] == 4 and st["entries"] == 2
    finally:
        disable_process_cache()
    assert process_cache() is None


def test_process_cache_shares_chain_programs_but_not_across_strategies(
        tiny):
    """Chain cohorts key on the strategy class: two fedcat servers share
    one compile, and a fedavg server can never be served the chain
    program (or vice versa)."""
    cache = enable_process_cache(maxsize=8)
    try:
        _build(tiny, None, "fedcat").round()
        miss0 = cache.stats()["misses"]
        _build(tiny, None, "fedcat").round()
        assert cache.stats()["misses"] == miss0       # shared
        assert cache.stats()["hits"] >= 1
        _build(tiny, None, "fedavg").round()
        assert cache.stats()["misses"] > miss0        # distinct program
    finally:
        disable_process_cache()
