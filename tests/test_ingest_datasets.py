"""CIFAR-100 / CINIC-10 ingest and dataset auto-detection.

Fake on-disk releases in the real formats: CIFAR-100 as the python
pickle (``train``/``test`` files with ``fine_labels``), CINIC-10 as the
class-directory layout (png images when Pillow is present, per-class
.npy stacks always). The synthetic fallback and CIFAR-10 path are
covered in tests/test_corpus_dataplane.py.
"""
import os
import pickle

import numpy as np
import pytest

from repro.data.corpus import Normalize
from repro.data.ingest import (
    CIFAR100_MEAN, CINIC10_MEAN, load_cifar100, load_cinic10,
    load_image_corpus,
)

_CLASSES = ("airplane", "automobile", "bird", "cat")


def _write_fake_cifar100(root, n_train=40, n_test=10):
    d = os.path.join(root, "cifar-100-python")
    os.makedirs(d, exist_ok=True)
    rng = np.random.default_rng(0)
    for name, n in (("train", n_train), ("test", n_test)):
        blob = {b"data": rng.integers(0, 256, size=(n, 3072),
                                      dtype=np.uint8),
                b"fine_labels": rng.integers(0, 100, size=n).tolist(),
                b"coarse_labels": rng.integers(0, 20, size=n).tolist()}
        with open(os.path.join(d, name), "wb") as f:
            pickle.dump(blob, f)
    return d


def _write_fake_cinic(root, per_class=3, use_png=False):
    rng = np.random.default_rng(0)
    for part in ("train", "test"):
        for cname in _CLASSES:
            cdir = os.path.join(root, part, cname)
            os.makedirs(cdir, exist_ok=True)
            imgs = rng.integers(0, 256, size=(per_class, 32, 32, 3),
                                dtype=np.uint8)
            if use_png:
                from PIL import Image
                for i in range(per_class):
                    Image.fromarray(imgs[i]).save(
                        os.path.join(cdir, f"img_{i:03d}.png"))
            else:
                np.save(os.path.join(cdir, "stack.npy"), imgs)
    return root


# ------------------------------------------------------------- CIFAR-100

def test_load_cifar100_pickles(tmp_path):
    d = _write_fake_cifar100(str(tmp_path))
    (xtr, ytr), (xte, yte) = load_cifar100(str(tmp_path))
    assert xtr.shape == (40, 32, 32, 3) and xtr.dtype == np.uint8
    assert ytr.shape == (40,) and ytr.dtype == np.int32
    assert xte.shape == (10, 32, 32, 3) and yte.shape == (10,)
    # fine labels, not coarse: range may exceed 20
    with open(os.path.join(d, "train"), "rb") as f:
        blob = pickle.load(f, encoding="bytes")
    np.testing.assert_array_equal(ytr, np.asarray(blob[b"fine_labels"]))
    # the release dir itself also resolves
    (x2, _), _ = load_cifar100(d)
    np.testing.assert_array_equal(x2, xtr)


def test_load_cifar100_missing_is_loud(tmp_path):
    with pytest.raises(FileNotFoundError, match="CIFAR-100"):
        load_cifar100(str(tmp_path))


# -------------------------------------------------------------- CINIC-10

def test_load_cinic10_npy_stacks(tmp_path):
    _write_fake_cinic(str(tmp_path), per_class=3)
    (xtr, ytr), (xte, yte) = load_cinic10(str(tmp_path))
    assert xtr.shape == (12, 32, 32, 3) and xtr.dtype == np.uint8
    # class ids follow sorted directory names
    np.testing.assert_array_equal(ytr, np.repeat(np.arange(4), 3))
    assert xte.shape == (12, 32, 32, 3)


def test_load_cinic10_png_images(tmp_path):
    pytest.importorskip("PIL")
    _write_fake_cinic(str(tmp_path), per_class=2, use_png=True)
    (xtr, ytr), _ = load_cinic10(str(tmp_path))
    assert xtr.shape == (8, 32, 32, 3) and xtr.dtype == np.uint8
    np.testing.assert_array_equal(ytr, np.repeat(np.arange(4), 2))
    # png round-trip is lossless: re-read matches the written pixels
    rng = np.random.default_rng(0)
    first = rng.integers(0, 256, size=(2, 32, 32, 3), dtype=np.uint8)
    np.testing.assert_array_equal(xtr[:2], first)


def test_load_cinic10_empty_class_dir_is_loud(tmp_path):
    cdir = tmp_path / "train" / "cat"
    cdir.mkdir(parents=True)
    (tmp_path / "test" / "cat").mkdir(parents=True)
    with pytest.raises(FileNotFoundError, match="no .npy"):
        load_cinic10(str(tmp_path))


def test_load_cinic10_missing_is_loud(tmp_path):
    with pytest.raises(FileNotFoundError, match="CINIC-10"):
        load_cinic10(str(tmp_path))


# ------------------------------------------------- detection + normalizers

def test_image_corpus_detects_cifar100(tmp_path):
    _write_fake_cifar100(str(tmp_path))
    src = load_image_corpus(str(tmp_path))
    assert src.source == "cifar100" and src.num_classes == 100
    assert isinstance(src.transform, Normalize)
    assert src.transform.mean == CIFAR100_MEAN


def test_image_corpus_detects_cinic10(tmp_path):
    _write_fake_cinic(str(tmp_path))
    src = load_image_corpus(str(tmp_path))
    assert src.source == "cinic10" and src.num_classes == 10
    assert src.transform.mean == CINIC10_MEAN


def test_image_corpus_explicit_dataset_overrides_detection(tmp_path):
    _write_fake_cinic(str(tmp_path))
    src = load_image_corpus(str(tmp_path), dataset="cinic10")
    assert src.source == "cinic10"
    with pytest.raises(FileNotFoundError, match="CIFAR-100"):
        load_image_corpus(str(tmp_path), dataset="cifar100")


def test_image_corpus_rejects_unknown_dataset(tmp_path):
    _write_fake_cinic(str(tmp_path))
    with pytest.raises(ValueError, match="unknown dataset"):
        load_image_corpus(str(tmp_path), dataset="imagenet")
    with pytest.raises(ValueError, match="needs a root"):
        load_image_corpus(None, dataset="cinic10")


def test_image_corpus_empty_root_is_loud(tmp_path):
    with pytest.raises(FileNotFoundError, match="dataset="):
        load_image_corpus(str(tmp_path))
