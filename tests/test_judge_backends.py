"""Judge-axis backend parity: ``MaxEntropyJudge(backend=...)`` must agree
with the float64 numpy oracle across class counts and degenerate inputs.

"xla" is the traced float32 leave-one-out sweep, "pallas" the class-tiled
``entropy_judge_sweep`` kernel (interpret mode on CPU CI). Agreement is
exact on verdicts (same greedy, same tolerance) and approximate on the
entropy value (float32 accumulation vs float64)."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.judgment import judge_np

BACKENDS = ("xla", "pallas")


def _soft(rng, m, c, alpha=0.2):
    return rng.dirichlet(np.full(c, alpha), size=m).astype(np.float32)


@pytest.mark.parametrize("c", [10, 100, 1000])
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_oracle_across_class_counts(rng, c, backend):
    m = 8
    soft = _soft(rng, m, c)
    sizes = rng.integers(10, 500, m).astype(np.float64)
    want_a, want_r, want_ent = judge_np(soft, sizes)
    got_a, got_r, got_ent = fl.MaxEntropyJudge(backend=backend)(soft, sizes)
    assert got_a == want_a
    assert got_r == want_r          # greedy-removal ORDER must match too
    assert got_ent == pytest.approx(want_ent, abs=5e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_single_client(rng, backend):
    """M=1: the judgment can never empty the set — sole client admitted."""
    soft = _soft(rng, 1, 10)
    sizes = np.asarray([42.0])
    a, r, ent = fl.MaxEntropyJudge(backend=backend)(soft, sizes)
    assert a == [0] and r == []
    assert ent == pytest.approx(judge_np(soft, sizes)[2], abs=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_all_zero_rows(rng, backend):
    """Degenerate soft labels (all-zero rows from dead clients) must not
    produce NaNs or verdict divergence vs the oracle."""
    m, c = 6, 100
    soft = _soft(rng, m, c)
    soft[1] = 0.0
    soft[4] = 0.0
    sizes = np.full(m, 10.0)
    want_a, want_r, want_ent = judge_np(soft, sizes)
    got_a, got_r, got_ent = fl.MaxEntropyJudge(backend=backend)(soft, sizes)
    assert got_a == want_a and got_r == want_r
    assert np.isfinite(got_ent)
    assert got_ent == pytest.approx(want_ent, abs=5e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_identical_labels_no_removal(backend):
    """Identical soft labels: no removal can raise entropy — admit all."""
    soft = np.tile(np.full((1, 10), 0.1, np.float32), (5, 1))
    sizes = np.full(5, 7.0)
    a, r, _ = fl.MaxEntropyJudge(backend=backend)(soft, sizes)
    assert a == list(range(5)) and r == []


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown judge backend"):
        fl.MaxEntropyJudge(backend="cuda")


def test_traced_forms_agree_with_host_call(rng):
    """Every registered judge's ``traced()`` returns a JudgmentResult whose
    mask/order reproduce the host-side __call__ verdict."""
    m, c = 6, 20
    soft = _soft(rng, m, c)
    sizes = rng.integers(5, 50, m).astype(np.float64)
    for judge in (fl.MaxEntropyJudge(), fl.PassThroughJudge(),
                  fl.BudgetedJudge(budget=3)):
        a, r, _ = judge(soft, sizes)
        res = judge.traced()(jnp.asarray(soft, jnp.float32),
                             jnp.asarray(sizes, jnp.float32))
        mask = np.asarray(res.mask)
        assert [i for i in range(m) if mask[i] > 0] == a
        if res.removal_order is not None:
            assert [int(k) for k in np.asarray(res.removal_order)
                    if k >= 0] == r
