"""The pluggable ``repro.fl`` server API: registry round-trips, legacy-shim
equivalence (bit-for-bit vs recorded seed-trainer histories), custom
components, and the bounded per-server jit cache."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.simulator import FedEntropyTrainer, FLConfig
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "seed_history.json")


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------- registry

def test_registry_roundtrip():
    assert fl.get("judge", "maxent") is fl.MaxEntropyJudge
    assert fl.get("selector", "pools") is fl.PoolSelector
    assert "fedentropy" in fl.names("composition")
    for comp in fl.names("composition"):
        recipe = fl.get("composition", comp)
        # every axis the recipe names must itself resolve
        fl.get("strategy", recipe.strategy)
        fl.get("selector", recipe.selector)
        fl.get("judge", recipe.judge)
        fl.get("aggregator", recipe.aggregator)
        if recipe.cluster is not None:
            assert fl.get("cluster", recipe.cluster) is not None
    # the cluster axis registers like any other kind
    assert fl.get("cluster", "ifca") is fl.IFCAAssigner
    assert fl.get("cluster", "fesem") is fl.FeSEMAssigner
    assert fl.get("composition", "ifca+maxent").cluster == "ifca"


def test_registry_unknown_name_errors():
    with pytest.raises(KeyError, match="no judge registered under 'nope'"):
        fl.get("judge", "nope")
    with pytest.raises(ValueError, match="unknown kind"):
        fl.register("flavor", "vanilla", object())


def test_register_and_build_custom_judge(tiny):
    """A user-defined Judge plugs through the registry by name."""
    data, params = tiny
    calls = []

    @fl.register("judge", "keep-first-two")
    class KeepFirstTwo:
        def __call__(self, soft_labels, sizes):
            calls.append(len(sizes))
            keep = list(range(min(2, len(sizes))))
            drop = list(range(2, len(sizes)))
            return keep, drop, 0.0

    server = fl.build("fedavg", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=0.5),
                      LocalSpec(epochs=1, batch_size=20),
                      judge="keep-first-two")
    rec = server.round()
    assert calls == [4]
    assert len(rec["positive"]) == 2 and len(rec["negative"]) == 2


def test_build_runs_fedentropy_and_fedavg(tiny):
    data, params = tiny
    for name in ("fedentropy", "fedavg"):
        server = fl.build(name, cnn.apply, params, data,
                          fl.ServerConfig(num_clients=8, participation=0.5),
                          LocalSpec(epochs=1, batch_size=20))
        rec = server.round()
        assert len(rec["selected"]) == 4
        assert len(rec["positive"]) + len(rec["negative"]) == 4
    # fedavg composition admits everyone (PassThroughJudge)
    assert not rec["negative"]


# ------------------------------------------------------- shim equivalence

_VARIANTS = {
    "fedentropy": ("fedavg", True, True),
    "fedavg_uniform": ("fedavg", False, False),
    "scaffold_fe": ("scaffold", True, True),
    "moon_nopools": ("moon", True, False),
}


def _histories_equal(got: list, want: list):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=1e-9)


@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_shim_reproduces_seed_histories_bitforbit(tiny, variant):
    """The refactored trainer must match histories recorded from the
    pre-refactor monolithic simulator on the same fixed seeds."""
    data, params = tiny
    with open(GOLDEN) as f:
        golden = json.load(f)[variant]
    strat, use_judgment, use_pools = _VARIANTS[variant]
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5,
                 use_judgment=use_judgment, use_pools=use_pools, seed=0),
        LocalSpec(strategy=strat, epochs=1, batch_size=20))
    for _ in range(len(golden["history"])):
        tr.round()
    _histories_equal(tr.history, golden["history"])
    assert _params_digest(tr.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)


def test_shim_equals_server_over_rounds(tiny):
    """FedEntropyTrainer and an explicitly-composed repro.fl.Server produce
    identical history (selected/positive/negative/entropy/comm) and params
    over several rounds on a fixed seed."""
    data, params = tiny
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5, seed=0),
        LocalSpec(epochs=1, batch_size=20))
    server = fl.build("fedentropy", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=0.5,
                                      seed=0),
                      LocalSpec(epochs=1, batch_size=20))
    for _ in range(4):
        tr.round()
        server.round()
    for g, w in zip(tr.history, server.history):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["entropy"] == pytest.approx(w["entropy"], nan_ok=True)
        assert g["comm"] == w["comm"]
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(tr.global_params)[0]),
        np.asarray(jax.tree.leaves(server.global_params)[0]))


def test_shim_uniform_ablation_updates_shadow_pools(tiny):
    data, params = tiny
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=8, participation=0.5, use_pools=False, seed=0),
        LocalSpec(epochs=1, batch_size=20))
    rec = tr.round()
    stats = tr.pools.stats()          # legacy observable, still maintained
    # legacy semantics: no select() ran on these pools, so positives stay
    # full and judged negatives accumulate alongside
    assert stats["positive"] == 8
    assert stats["negative"] == len(rec["negative"])


def test_conflicting_localspec_strategy_rejected(tiny):
    """A LocalSpec naming a different update rule than the composition is
    an error, not a silent override."""
    data, params = tiny
    with pytest.raises(ValueError, match="conflicts with the 'fedavg'"):
        fl.build("fedentropy", cnn.apply, params, data,
                 fl.ServerConfig(num_clients=8, participation=0.5),
                 LocalSpec(strategy="scaffold"))
    # the matching name (or the default) is fine
    fl.build("scaffold", cnn.apply, params, data,
             fl.ServerConfig(num_clients=8, participation=0.5),
             LocalSpec(strategy="scaffold"))


# ------------------------------------------------- strategy state pytrees

def test_strategy_state_is_explicit_pytree(tiny):
    data, params = tiny
    server = fl.build("scaffold", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=0.5),
                      LocalSpec(strategy="scaffold", epochs=1,
                                batch_size=20))
    assert set(server.state) == {"c_global", "c_local"}
    before = jax.tree.map(lambda x: x.copy(), server.state["c_global"])
    server.round()
    moved = any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(
        jax.tree.leaves(before), jax.tree.leaves(server.state["c_global"])))
    assert moved


# ------------------------------------------------------ bounded jit cache

def test_bounded_jit_cache_evicts_lru():
    cache = fl.BoundedJitCache(2)
    makes = []
    for key in ("a", "b", "a", "c", "b"):
        cache.get(key, lambda k=key: makes.append(k) or k)
    # "a" was refreshed before "c" evicted "b"; re-getting "b" recompiles
    assert makes == ["a", "b", "c", "b"]
    assert len(cache) == 2


def test_server_owns_its_cache(tiny):
    data, params = tiny
    cfg = fl.ServerConfig(num_clients=8, participation=0.5, jit_cache_size=2)
    s1 = fl.build("fedavg", cnn.apply, params, data, cfg,
                  LocalSpec(epochs=1, batch_size=20))
    s2 = fl.build("fedavg", cnn.apply, params, data, cfg,
                  LocalSpec(epochs=1, batch_size=20))
    s1.round()
    assert len(s1._jit_cache) == 1 and len(s2._jit_cache) == 0


# -------------------------------------------- selector / eval edge guards

def test_pool_selector_clamps_oversized_draw(tiny):
    """participation * num_clients > num_clients must clamp to the
    population (like UniformSelector/QueueSelector), not over-draw."""
    sel = fl.PoolSelector(8)
    got = sel.select(12)
    assert sorted(got) == list(range(8))          # everyone, exactly once
    # end to end: an oversaturated config still runs a full round
    data, params = tiny
    server = fl.build("fedentropy", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=1.5,
                                      seed=0),
                      LocalSpec(epochs=1, batch_size=20))
    rec = server.round()
    assert sorted(rec["selected"]) == list(range(8))
    assert len(rec["positive"]) + len(rec["negative"]) == 8


def test_evaluate_empty_eval_set_fails_loudly(tiny):
    """n=0 raises a clear ValueError instead of dying in range(0, 0, 0)."""
    data, params = tiny
    server = fl.build("fedavg", cnn.apply, params, data,
                      fl.ServerConfig(num_clients=8, participation=0.5),
                      LocalSpec(epochs=1, batch_size=20))
    x = jnp.zeros((0, 16, 16, 3), jnp.float32)
    y = jnp.zeros((0,), jnp.int32)
    with pytest.raises(ValueError, match="empty eval set"):
        server.evaluate(x, y)
