"""FedCAT device-concatenation compositions: golden-history regression of
``Server`` vs ``PipelinedServer`` (speculation on AND off, forced shard),
the group-size-1 reduction to plain fedavg, chain-truncating judgment,
and misspeculation fallback with group dispatch."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fedcat_history.json")
GOLDEN_SEED = os.path.join(os.path.dirname(__file__), "golden",
                           "seed_history.json")

# composition name per golden variant (recorded by golden/record_fedcat.py)
_VARIANTS = {"fedcat": "fedcat", "fedcat_maxent": "fedcat+maxent"}


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def _build(tiny, name="fedcat", engine=None, runtime=None, group_size=2,
           **overrides):
    data, params = tiny
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0, group_size=group_size),
                    LocalSpec(epochs=1, batch_size=20),
                    engine=engine, runtime=runtime, **overrides)


def _assert_matches_golden(history, golden, *, groups=None):
    assert len(history) == len(golden)
    for g, w in zip(history, golden):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=1e-9)
    if groups is not None:
        assert groups == golden[-1]["groups"]


# ----------------------------------------------------- golden equivalence

@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_sequential_server_matches_golden(tiny, variant):
    with open(GOLDEN) as f:
        golden = json.load(f)[variant]
    server = _build(tiny, _VARIANTS[variant])
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"],
                           groups=server.selector.last_groups)
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)


@pytest.mark.parametrize("speculate", [False, True],
                         ids=["spec-off", "spec-on"])
@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_pipelined_matches_golden(tiny, variant, speculate):
    """ISSUE acceptance: PipelinedServer reproduces the fedcat goldens
    bit-for-bit with speculation on AND off — the group (not the device)
    is the dispatch unit, and speculative group assignment on the selector
    copy must replay identically."""
    with open(GOLDEN) as f:
        golden = json.load(f)[variant]
    server = _build(tiny, _VARIANTS[variant], engine="pipelined",
                    runtime=RuntimeConfig(speculate=speculate))
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)
    if speculate:
        for rec in server.history:
            assert isinstance(rec["spec_hit"], bool)


def test_forced_shard_matches_golden(tiny):
    """shard=True partitions whole groups over the ("clients",) mesh; the
    chain outputs must still match the sequential golden."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedcat_maxent"]
    server = _build(tiny, "fedcat+maxent", engine="pipelined",
                    runtime=RuntimeConfig(shard=True))
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-6)


# ------------------------------------------------- group-size-1 reduction

def test_group_size_1_is_bitforbit_fedavg(tiny):
    """ISSUE acceptance: with group size 1 every device is its own chain,
    so the fedcat round history is bit-for-bit the plain fedavg history
    recorded in the seed golden (same selector stream: catgroups wraps
    uniform with the identical seed)."""
    with open(GOLDEN_SEED) as f:
        golden = json.load(f)["fedavg_uniform"]
    server = _build(tiny, "fedcat", group_size=1)
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)


def test_group_size_1_equals_live_fedavg_params(tiny):
    """Stronger than the digest: the K=1 chain program and the vmapped
    fedavg program produce identical parameter arrays."""
    data, params = tiny
    fa = fl.build("fedavg", cnn.apply, params, data,
                  fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
                  LocalSpec(epochs=1, batch_size=20))
    k1 = _build(tiny, "fedcat", group_size=1)
    for _ in range(3):
        fa.round()
        k1.round()
    for a, b in zip(jax.tree.leaves(fa.global_params),
                    jax.tree.leaves(k1.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------- judgment filters the chain

def test_rejection_truncates_chain_not_whole_group(tiny):
    """A rejected device cuts its chain at the last stage it never touched:
    the admitted prefix still aggregates (BudgetedJudge forces exactly two
    rejections per round, so truncation happens every round)."""
    server = _build(tiny, "fedcat", judge=fl.BudgetedJudge(budget=2))
    before = _params_digest(server.global_params)
    for _ in range(2):
        rec = server.round()
        assert len(rec["positive"]) == 2 and len(rec["negative"]) == 2
    assert _params_digest(server.global_params) != pytest.approx(before)


def test_all_rejected_keeps_global_params(tiny):
    """If judgment empties every chain the global model must be kept, not
    zeroed by an empty weighted average."""
    _, params = tiny

    @fl.register("judge", "reject-all")
    class RejectAll:
        def __call__(self, soft_labels, sizes):
            return [], list(range(len(sizes))), float("nan")

    server = _build(tiny, "fedcat", judge="reject-all")
    server.round()
    for a, b in zip(jax.tree.leaves(server.global_params),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------ misspeculation + groups

class _WrongSpeculationJudge(fl.MaxEntropyJudge):
    """Oracle = real maxent; traced form admits everyone, so every round
    with a rejection misspeculates and its group dispatch is re-issued."""

    def traced(self):
        return fl.PassThroughJudge().traced()


def test_misspeculation_redispatches_groups_and_stays_correct(tiny):
    """A wrong speculative verdict discards the in-flight group dispatch;
    history and params still match the sequential golden."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedcat_maxent"]
    server = _build(tiny, "fedcat+maxent", engine="pipelined",
                    runtime=RuntimeConfig(speculate=True),
                    judge=_WrongSpeculationJudge())
    for _ in range(len(golden["history"])):
        server.round()
    _assert_matches_golden(server.history, golden["history"])
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)
    for prev, rec in zip(server.history, server.history[1:]):
        assert rec["redispatched"] == (not prev["spec_hit"])
        assert prev["spec_hit"] == (not prev["negative"])
