"""The streaming host-resident data plane (`repro.data.stream`).

Plane equivalence is the contract under test: `HostCorpus` streamed
control-plane stats match `ClientCorpus` dense stats bit-exactly,
cohorts are bit-equal across planes (memory-mapped stores included),
and streaming-plane Server / PipelinedServer histories reproduce the
recorded goldens bit-for-bit with speculation on and off — where the
speculated selection doubles as the `CohortPrefetcher` target and a
misprediction falls back to a synchronous gather. Also covered: the
thread-safe jit caches the prefetch thread requires, the packed `.npy`
ingest cache, and plane-aware memory accounting.
"""
import json
import os
import pickle
import threading

import jax
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.corpus import ClientCorpus, DataQueue, Normalize
from repro.data.ingest import load_image_corpus, packed_cache_dir
from repro.data.partition import partition, stack_clients
from repro.data.stream import HostCorpus, as_data_plane
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig
from repro.fl.runtime.compile_cache import (
    disable_process_cache, enable_process_cache,
)
from repro.fl.server import BoundedJitCache
from repro.models import cnn

SEED_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                           "seed_history.json")
UNEVEN_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                             "uneven_history.json")
PAPER_N, CLASSES = 100, 10


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


@pytest.fixture(scope="module")
def paper():
    """Identical to the setup tests/golden/record_uneven.py recorded."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=CLASSES, train_per_class=2 * PAPER_N, test_per_class=10,
        hw=16, noise=0.9, seed=0)
    parts = partition("case1", ytr, PAPER_N, CLASSES, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=10)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16,
                      num_classes=CLASSES)
    return data, params


# ------------------------------------------------- streamed stats parity

def test_streamed_stats_match_dense_bit_exactly(tiny):
    """One-pass chunked stats == dense corpus stats, bit for bit — with a
    chunk size small enough that chunking actually happens."""
    data, _ = tiny
    dense = ClientCorpus.from_stacked(dict(data))
    streamed = HostCorpus(dict(data), stats_chunk=3)     # 8 clients -> 3
    np.testing.assert_array_equal(streamed.sizes(), dense.sizes())
    np.testing.assert_array_equal(streamed.label_histograms(),
                                  dense.label_histograms())
    np.testing.assert_array_equal(streamed.label_entropy(),
                                  dense.label_entropy())
    # explicit class width streams a fresh (cached) pass
    np.testing.assert_array_equal(streamed.label_histograms(7),
                                  dense.label_histograms(7))
    assert streamed.label_histograms(7) is streamed.label_histograms(7)


def test_streamed_stats_match_dense_paper_scale(paper):
    data, _ = paper
    dense = ClientCorpus.from_stacked(dict(data))
    streamed = HostCorpus(dict(data), stats_chunk=7)     # N=100 -> chunks
    np.testing.assert_array_equal(streamed.sizes(), dense.sizes())
    np.testing.assert_array_equal(streamed.label_histograms(),
                                  dense.label_histograms())
    np.testing.assert_array_equal(streamed.label_entropy(),
                                  dense.label_entropy())


# ---------------------------------------------------- cohort equivalence

def test_cohort_bit_equal_across_planes(tiny):
    """Host gather + upload + traced finish == resident jitted gather,
    with and without a queue mask, transform included."""
    data, _ = tiny
    t = Normalize(scale=1 / 255.0, mean=(0.4, 0.5, 0.6),
                  std=(0.2, 0.3, 0.4))
    dense = ClientCorpus(dict(data), transform=t)
    streamed = HostCorpus(dict(data), transform=t)
    idx = np.asarray([5, 0, 3, 3])
    active = np.asarray([7, 1, 20, 4])
    for act in (None, active):
        a = dense.cohort(idx, active=act)
        b = streamed.cohort(idx, active=act)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k])), k
            assert a[k].dtype == b[k].dtype


def test_mmap_store_cohorts_and_stats(tiny, tmp_path):
    """A save/open round-trip memory-maps the store (host_is_mmap) and
    serves identical stats and cohorts; the transform policy rides in
    meta.json."""
    data, _ = tiny
    t = Normalize(scale=1 / 2.0, mean=(0.1,), std=(0.9,))
    src = HostCorpus(dict(data), transform=t)
    d = src.save(str(tmp_path / "corpus"))
    mapped = HostCorpus.open(d)
    assert mapped.transform == t
    assert mapped.memory_report()["host_is_mmap"]
    np.testing.assert_array_equal(mapped.sizes(), src.sizes())
    np.testing.assert_array_equal(mapped.label_histograms(),
                                  src.label_histograms())
    idx = np.asarray([1, 4, 2])
    a, b = src.cohort(idx), mapped.cohort(idx)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_signature_keys_the_plane(tiny):
    """Streaming signatures are tagged distinct from resident ones: a
    compiled program can never be served across planes."""
    data, _ = tiny
    dense = ClientCorpus.from_stacked(dict(data))
    streamed = HostCorpus.from_stacked(dict(data))
    assert dense.signature() != streamed.signature()
    assert streamed.signature()[0] == "stream"
    # and the plane survives corpus conversion
    assert as_data_plane(streamed, "resident").signature() \
        == dense.signature()


# ------------------------------------------------------ plane resolution

def test_as_data_plane_modes(tiny):
    data, _ = tiny
    assert as_data_plane(dict(data)).plane == "resident"
    assert as_data_plane(dict(data), "streaming").plane == "streaming"
    # "auto" passes constructed corpora through untouched
    hc = HostCorpus.from_stacked(dict(data))
    assert as_data_plane(hc) is hc
    cc = ClientCorpus.from_stacked(dict(data))
    assert as_data_plane(cc) is cc
    # over-budget dicts stream; explicit planes convert
    assert as_data_plane(dict(data), resident_budget=16).plane \
        == "streaming"
    back = as_data_plane(hc, "resident")
    assert isinstance(back, ClientCorpus)
    with pytest.raises(ValueError, match="unknown data plane"):
        as_data_plane(dict(data), "hybrid")


# --------------------------------------------------- golden equivalence

def _assert_matches(history, golden, *, exact_entropy=True):
    for rec, g in zip(history, golden):
        assert rec["selected"] == g["selected"]
        assert rec["positive"] == g["positive"]
        assert rec["negative"] == g["negative"]
        if exact_entropy:
            assert rec["entropy"] == pytest.approx(float(g["entropy"]),
                                                   abs=1e-9)
        else:
            assert rec["entropy"] == pytest.approx(float(g["entropy"]),
                                                   abs=1e-6)


@pytest.mark.parametrize("engine,runtime", [
    (None, None),
    ("pipelined", RuntimeConfig(speculate=False)),
    ("pipelined", RuntimeConfig(speculate=True)),
])
def test_streaming_plane_reproduces_seed_golden(tiny, engine, runtime):
    """ISSUE acceptance: the streaming plane reproduces the resident
    plane's recorded histories bit-for-bit, speculation on and off; the
    speculative runs also prefetch every confirmed cohort."""
    with open(SEED_GOLDEN) as f:
        golden = json.load(f)["fedentropy"]["history"][:3]
    data, params = tiny
    server = fl.build(
        "fedentropy", cnn.apply, params, dict(data),
        fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
        LocalSpec(epochs=1, batch_size=20),
        engine=engine, runtime=runtime, data_plane="streaming")
    assert isinstance(server.corpus, HostCorpus)
    for _ in range(len(golden)):
        server.round()
    _assert_matches(server.history, golden)
    stats = server.corpus.prefetch_stats()
    if runtime is not None and runtime.speculate:
        hits = sum(r["spec_hit"] for r in server.history)
        assert stats["hits"] == hits > 0
        assert stats["hit_rate"] == 1.0
    else:
        assert stats["hits"] == stats["cancelled"] == 0


@pytest.mark.parametrize("variant,comp", [
    ("fedentropy", "fedentropy"),
    ("fedentropy_queue", "fedentropy+queue"),
])
def test_streaming_plane_reproduces_uneven_golden(paper, variant, comp):
    """Paper-scale N=100 goldens (fedentropy + the queue selector, whose
    data schedule must ride the prefetch) hold on the streaming plane for
    Server and PipelinedServer with speculation on and off. Ints are
    exact; entropy floats tolerate compiled-program-shape differences on
    multi-device CI (same policy as test_uneven_shard)."""
    with open(UNEVEN_GOLDEN) as f:
        golden = json.load(f)[variant]["history"]
    data, params = paper
    cfg = fl.ServerConfig(num_clients=PAPER_N, participation=0.1, seed=0,
                          group_size=2)
    local = LocalSpec(epochs=1, batch_size=10)
    engines = {
        "seq": fl.build(comp, cnn.apply, params, dict(data), cfg, local,
                        data_plane="streaming"),
        "off": fl.build(comp, cnn.apply, params, dict(data), cfg, local,
                        engine="pipelined", runtime=RuntimeConfig(),
                        data_plane="streaming"),
        "spec": fl.build(comp, cnn.apply, params, dict(data), cfg, local,
                         engine="pipelined",
                         runtime=RuntimeConfig(speculate=True),
                         data_plane="streaming"),
    }
    for server in engines.values():
        assert isinstance(server.corpus, HostCorpus)
        for _ in range(len(golden)):
            server.round()
    for name, server in engines.items():
        assert [(r["selected"], r["positive"], r["negative"],
                 r["comm"]["total_bytes"]) for r in server.history] == [
            (g["selected"], g["positive"], g["negative"],
             g["total_bytes"]) for g in golden], name
        _assert_matches(server.history, golden, exact_entropy=False)
    # spec-on vs spec-off run identical programs: bit-identical entropy
    for a, b in zip(engines["off"].history, engines["spec"].history):
        assert a["entropy"] == b["entropy"]


# --------------------------------------------- prefetch + misprediction

def test_prefetcher_hit_miss_cancel(tiny):
    data, _ = tiny
    hc = HostCorpus.from_stacked(dict(data))
    idx = np.asarray([1, 3, 5])
    plain = {k: np.asarray(v) for k, v in hc.cohort(idx).items()}
    # hit: staged upload consumed, bit-equal to the synchronous gather
    hc.prefetch(idx)
    hit = hc.cohort(idx)
    for k in plain:
        np.testing.assert_array_equal(plain[k], np.asarray(hit[k]))
    assert hc.prefetch_stats()["hits"] == 1
    # miss: pending key differs -> discarded, sync gather still correct
    hc.prefetch(np.asarray([0, 2, 4]))
    missed = hc.cohort(idx)
    for k in plain:
        np.testing.assert_array_equal(plain[k], np.asarray(missed[k]))
    assert hc.prefetch_stats()["misses"] == 1
    # queue mask participates in the match key
    hc.prefetch(idx, np.asarray([1, 2, 3]))
    _ = hc.cohort(idx, active=np.asarray([3, 2, 1]))
    assert hc.prefetch_stats()["misses"] == 2
    # cancel: staged buffers dropped without being consumed
    hc.prefetch(idx)
    hc.cancel_prefetch()
    assert hc.prefetch_stats()["cancelled"] == 1
    assert hc.prefetch_stats()["hits"] == 1
    # double-buffering reuses the two staging buffers (bounded memory)
    nb = hc.prefetcher().staging_nbytes
    for _ in range(4):
        hc.prefetch(idx)
        hc.cohort(idx)
    assert hc.prefetcher().staging_nbytes == nb


def test_prefetcher_depth_ring(tiny):
    """depth>1 queues multiple predictions FIFO; depth=1 keeps the
    historical single-slot overwrite semantics bit-for-bit."""
    data, _ = tiny
    hc = HostCorpus(dict(data), prefetch_depth=2)
    assert hc.prefetcher().depth == 2
    a, b, c = (np.asarray([0, 1]), np.asarray([2, 3]), np.asarray([4, 5]))
    plain = {k: {kk: np.asarray(v) for kk, v in hc.cohort(i).items()}
             for k, i in zip("abc", (a, b, c))}
    # two in flight, consumed in order: both hits, both bit-equal
    hc.prefetch(a)
    hc.prefetch(b)
    for key, idx in (("a", a), ("b", b)):
        got = hc.cohort(idx)
        for k in plain[key]:
            np.testing.assert_array_equal(plain[key][k],
                                          np.asarray(got[k]))
    assert hc.prefetch_stats()["hits"] == 2
    assert hc.prefetch_stats()["misses"] == 0
    # a third start evicts the OLDEST queued prediction (cancelled)
    hc.prefetch(a)
    hc.prefetch(b)
    hc.prefetch(c)
    assert hc.prefetch_stats()["cancelled"] == 1
    # stale prediction ahead of the match is discarded as a miss
    got = hc.cohort(c)
    for k in plain["c"]:
        np.testing.assert_array_equal(plain["c"][k], np.asarray(got[k]))
    assert hc.prefetch_stats()["misses"] == 1
    assert hc.prefetch_stats()["hits"] == 3
    # cancel drops everything still queued
    hc.prefetch(a)
    hc.prefetch(b)
    hc.cancel_prefetch()
    assert hc.prefetch_stats()["cancelled"] == 3
    # the ring stays bounded at depth+1 buffers under sustained traffic
    for _ in range(4):
        hc.prefetch(a)
        hc.prefetch(b)
        hc.cohort(a)
        hc.cohort(b)
    nb = hc.prefetcher().staging_nbytes
    hc.prefetch(a)
    hc.prefetch(b)
    hc.cohort(a)
    hc.cohort(b)
    assert hc.prefetcher().staging_nbytes == nb
    with pytest.raises(ValueError, match="depth"):
        HostCorpus(dict(data), prefetch_depth=0)


class _WrongSpeculationJudge(fl.MaxEntropyJudge):
    """Oracle = real maxent; traced form always admits everyone, so every
    round with a rejection misspeculates."""

    def traced(self):
        return fl.PassThroughJudge().traced()


def test_misprediction_cancels_prefetch_and_stays_golden(tiny):
    """A selector misprediction discards the staged cohort and falls back
    to a synchronous gather — history still matches golden bit-for-bit."""
    with open(SEED_GOLDEN) as f:
        golden = json.load(f)["fedentropy"]["history"]
    data, params = tiny
    server = fl.build(
        "fedentropy", cnn.apply, params, dict(data),
        fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
        LocalSpec(epochs=1, batch_size=20),
        judge=_WrongSpeculationJudge(), engine="pipelined",
        runtime=RuntimeConfig(speculate=True), data_plane="streaming")
    for _ in range(len(golden)):
        server.round()
    _assert_matches(server.history, golden)
    stats = server.corpus.prefetch_stats()
    misses = sum(not r["spec_hit"] for r in server.history)
    hits = sum(r["spec_hit"] for r in server.history)
    assert misses > 0                     # the judge guarantees misses
    assert stats["cancelled"] >= misses - 1   # last round may be pending
    assert stats["hits"] <= hits
    for prev, rec in zip(server.history, server.history[1:]):
        assert rec["redispatched"] == (not prev["spec_hit"])


def test_prefetch_worker_errors_surface_on_take(tiny):
    """An exception on the staging thread re-raises in the consumer, not
    silently on a daemon thread."""
    data, _ = tiny
    hc = HostCorpus.from_stacked(dict(data))
    idx = np.asarray([0, 1])
    hc.prefetch(idx)
    hc.prefetcher().take(idx, None)       # drain the good one
    hc.prefetch(np.asarray([0, 10 ** 6]))  # out-of-bounds host gather
    with pytest.raises(IndexError):
        hc.cohort(np.asarray([0, 10 ** 6]))


# ------------------------------------------------- thread-safe jit caches

def test_bounded_jit_cache_thread_safe():
    """Concurrent gets of one key build exactly once; concurrent distinct
    keys never corrupt the LRU (the prefetch-thread requirement)."""
    cache = BoundedJitCache(maxsize=64)
    built = []
    barrier = threading.Barrier(8)
    errors = []

    def work(tid):
        try:
            barrier.wait()
            for i in range(200):
                cache.get(("shared", i % 10),
                          lambda i=i: built.append(i) or i)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(built) == 10               # one construction per key
    assert len(cache) == 10


def test_process_cache_counts_under_threads():
    cache = enable_process_cache(maxsize=32)
    try:
        threads = [threading.Thread(
            target=lambda: [cache.get(("k", i % 4), lambda: object())
                            for i in range(100)]) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        s = cache.stats()
        assert s["hits"] + s["misses"] == 400
        assert s["misses"] == 4           # one build per key
    finally:
        disable_process_cache()


# ------------------------------------------------------ memory accounting

def test_memory_report_is_plane_aware():
    rng = np.random.default_rng(0)
    data = {"x": rng.integers(0, 255, (512, 16, 8), dtype=np.uint8),
            "y": rng.integers(0, 10, (512, 16)).astype(np.int32),
            "w": np.ones((512, 16), np.float32)}
    dense = ClientCorpus.from_stacked(dict(data))
    rep = dense.memory_report()
    assert rep["plane"] == "resident"
    assert rep["device_resident_bytes"] > 0
    assert rep["host_mapped_bytes"] == 0 and rep["staging_nbytes"] == 0
    streamed = HostCorpus.from_stacked(dict(data))
    rep = streamed.memory_report()
    assert rep["plane"] == "streaming"
    assert rep["host_mapped_bytes"] == streamed.nbytes
    assert rep["device_resident_bytes"] == 0       # nothing uploaded yet
    # device bytes after a gather are exactly one cohort, not O(N)
    m = 8
    streamed.cohort(np.arange(m))
    rep = streamed.memory_report()
    assert rep["device_resident_bytes"] == streamed.cohort_nbytes(m)
    assert rep["device_resident_bytes"] * 16 < streamed.nbytes


def test_streaming_device_bytes_track_cohort_not_n(tiny):
    """Growing N leaves the uploaded bytes untouched (O(|S_t|))."""
    data, _ = tiny
    small = HostCorpus.from_stacked(dict(data))
    big = HostCorpus.from_stacked(
        {k: np.concatenate([np.asarray(v)] * 8) for k, v in data.items()})
    idx = np.asarray([0, 2, 4])
    small.cohort(idx)
    big.cohort(idx)
    assert big.device_nbytes() == small.device_nbytes()
    assert big.nbytes == 8 * small.nbytes


# ------------------------------------------------- packed .npy ingest cache

def _write_fake_cifar10(root, n=16):
    d = os.path.join(root, "cifar-10-batches-py")
    os.makedirs(d, exist_ok=True)
    rng = np.random.default_rng(0)
    for name in (*[f"data_batch_{i}" for i in range(1, 6)], "test_batch"):
        blob = {b"data": rng.integers(0, 256, size=(n, 3072),
                                      dtype=np.uint8),
                b"labels": rng.integers(0, 10, size=n).tolist()}
        with open(os.path.join(d, name), "wb") as f:
            pickle.dump(blob, f)
    return d


def test_ingest_writes_and_reopens_packed_cache(tmp_path):
    """First load packs .npy splits next to the dataset; the second load
    memory-maps them (and survives deleting the pickles entirely)."""
    root = str(tmp_path)
    _write_fake_cifar10(root)
    first = load_image_corpus(root)
    cache_dir = packed_cache_dir(root, "cifar10")
    assert os.path.isfile(os.path.join(cache_dir, "meta.json"))
    second = load_image_corpus(root)
    assert isinstance(second.train[0], np.memmap)
    np.testing.assert_array_equal(np.asarray(first.train[0]),
                                  np.asarray(second.train[0]))
    np.testing.assert_array_equal(np.asarray(first.test[1]),
                                  np.asarray(second.test[1]))
    assert second.source == "cifar10" and second.num_classes == 10
    # the packed cache alone is enough — auto-detection finds it after
    # the raw release is gone
    import shutil
    shutil.rmtree(os.path.join(root, "cifar-10-batches-py"))
    third = load_image_corpus(root)
    np.testing.assert_array_equal(np.asarray(first.train[1]),
                                  np.asarray(third.train[1]))
    # cache=False goes back to the raw loader, which is now gone
    with pytest.raises(FileNotFoundError):
        load_image_corpus(root, cache=False)


def test_host_corpus_maps_packed_ingest_directly(tmp_path):
    """The packed cache is a plain .npy layout HostCorpus can stack from
    without copying the full set into private memory."""
    root = str(tmp_path)
    _write_fake_cifar10(root, n=16)
    load_image_corpus(root)                   # writes the packed cache
    src = load_image_corpus(root)             # memory-mapped splits
    xtr, ytr = src.train
    parts = partition("case1", np.asarray(ytr), 4, 10, seed=0)
    stacked = stack_clients(np.asarray(xtr), np.asarray(ytr), parts,
                            batch_multiple=4)
    hc = HostCorpus(stacked, transform=src.transform)
    dense = ClientCorpus(dict(stacked), transform=src.transform)
    idx = np.asarray([0, 3])
    a, b = dense.cohort(idx), hc.cohort(idx)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# ------------------------------------------------------ queue + schedule

def test_queue_selector_binds_streaming_plane(tiny):
    """bind_data duck-types the plane: the queue selector ranks off the
    streamed stats and its schedule applies inside the streamed finish."""
    data, _ = tiny
    hc = HostCorpus.from_stacked(dict(data))
    cc = ClientCorpus.from_stacked(dict(data))
    qs = fl.QueueSelector(8, eps=1.0, seed=0,
                          queue=DataQueue(start_frac=0.5,
                                          rounds_to_full=4))
    qh = fl.QueueSelector(8, eps=1.0, seed=0,
                          queue=DataQueue(start_frac=0.5,
                                          rounds_to_full=4))
    qs.bind_data(cc)
    qh.bind_data(hc)
    np.testing.assert_array_equal(qs._entropy, qh._entropy)
    np.testing.assert_array_equal(qs._sizes, qh._sizes)
    sel_a, sel_b = qs.select(4), qh.select(4)
    assert sel_a == sel_b
    np.testing.assert_array_equal(qs.data_schedule(sel_a),
                                  qh.data_schedule(sel_b))
