"""The async buffered engine: reduction guarantee vs the sequential
``Server`` (golden AND live, bit-for-bit), straggler-clock determinism,
staleness damping, admission comm savings, the judge admission entry
points, and the engine/runtime registry error matrix."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.judges import admit_candidates
from repro.fl.runtime import (
    ArrivalClock, AsyncBufferedServer, AsyncConfig, RuntimeConfig,
    staleness_weights,
)
from repro.models import cnn

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
SEQ_GOLDEN = os.path.join(GOLDEN_DIR, "seed_history.json")
ASYNC_GOLDEN = os.path.join(GOLDEN_DIR, "async_history.json")

# same tolerance policy as test_runtime_engine.py: ints exact everywhere,
# entropy floats exact on the single device the goldens were recorded on,
# tolerant under the forced multi-device CI mesh (different compiled
# program shapes perturb low float bits)
_SINGLE_DEVICE = len(jax.devices()) == 1
ENT_ATOL = 1e-9 if _SINGLE_DEVICE else 1e-6

_STRAGGLER = AsyncConfig(clock="straggler", latency_scale=1.0,
                         straggler_frac=0.25, straggler_factor=8.0,
                         staleness_alpha=0.5, seed=0)


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _build(tiny, name="fedentropy", runtime=None, engine="async",
           **overrides):
    data, params = tiny
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20),
                    engine=engine, runtime=runtime, **overrides)


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def _assert_ints_match(rec, want):
    assert rec["selected"] == want["selected"]
    assert rec["positive"] == want["positive"]
    assert rec["negative"] == want["negative"]
    assert rec["comm"]["total_bytes"] == want["total_bytes"]
    ent = float(want["entropy"])
    if np.isnan(ent):
        assert np.isnan(rec["entropy"])
    else:
        assert rec["entropy"] == pytest.approx(ent, abs=ENT_ATOL)


# ------------------------------------------------------ reduction guarantee

@pytest.mark.parametrize("variant,comp", [("fedentropy", "fedentropy"),
                                          ("fedavg_uniform", "fedavg")])
def test_async_reduction_matches_sequential_golden(tiny, variant, comp):
    """ISSUE acceptance: K=|cohort| + zero-latency clock + damping off is
    bit-for-bit the sequential ``Server`` — checked against the SEQUENTIAL
    engine's own recorded golden, not an async-specific one."""
    with open(SEQ_GOLDEN) as f:
        golden = json.load(f)[variant]
    server = _build(tiny, comp, runtime=AsyncConfig())
    assert isinstance(server, AsyncBufferedServer)
    for _ in range(len(golden["history"])):
        rec = server.round()
        assert rec["staleness"] == [0] * len(rec["selected"])
        assert rec["flush_time"] == 0.0
    for rec, want in zip(server.history, golden["history"]):
        _assert_ints_match(rec, want)
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-7)


def test_async_reduction_matches_live_sequential(tiny):
    """Same reduction against a live sequential server: histories equal and
    params bitwise identical (same compiled program, same reduction)."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20))
    asy = _build(tiny)
    for _ in range(3):
        a, b = seq.round(), asy.round()
        for k in ("round", "selected", "positive", "negative"):
            assert a[k] == b[k]
        assert a["comm"] == b["comm"]
        assert b["entropy"] == pytest.approx(a["entropy"], abs=ENT_ATOL)
    for x, y in zip(jax.tree.leaves(seq.global_params),
                    jax.tree.leaves(asy.global_params)):
        if _SINGLE_DEVICE:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)


# --------------------------------------------------------- straggler clock

def test_straggler_matches_async_golden(tiny):
    """The straggler-clock variant pins the async-specific record fields:
    virtual flush times, staleness distributions, arrival sequence ids."""
    with open(ASYNC_GOLDEN) as f:
        golden = json.load(f)["fedentropy_straggler"]
    server = _build(tiny, runtime=_STRAGGLER)
    for _ in range(len(golden["history"])):
        server.round()
    for rec, want in zip(server.history, golden["history"]):
        _assert_ints_match(rec, want)
        assert rec["staleness"] == want["staleness"]
        assert rec["seq"] == want["seq"]
        assert rec["admitted_seq"] == want["admitted_seq"]
        assert rec["flush_time"] == pytest.approx(
            float(want["flush_time"]), rel=1e-12)
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=1e-6)
    # the heavy tail actually produced stale admissions
    assert any(max(r["staleness"]) > 0 for r in server.history)


def test_straggler_run_is_deterministic(tiny):
    """No wall-clock anywhere: two identical builds stream identically."""
    h1 = [_build(tiny, runtime=_STRAGGLER).round() for _ in range(1)]
    s2 = _build(tiny, runtime=_STRAGGLER)
    h2 = [s2.round()]
    for a, b in zip(h1, h2):
        assert a["selected"] == b["selected"]
        assert a["staleness"] == b["staleness"]
        assert a["flush_time"] == b["flush_time"]
        assert a["seq"] == b["seq"]


def test_flushes_partition_admitted_updates(tiny):
    """Every screened arrival lands in exactly one flush; admitted ids are
    a subset of the flush's arrivals (the deterministic twin of the
    hypothesis property in test_async_properties.py)."""
    server = _build(tiny, runtime=_STRAGGLER)
    recs = [server.round() for _ in range(4)]
    seen: set = set()
    for rec in recs:
        batch = set(rec["seq"])
        assert len(batch) == len(rec["seq"])       # no duplicate arrivals
        assert not (batch & seen)                  # disjoint across flushes
        assert set(rec["admitted_seq"]) <= batch
        assert len(rec["admitted_seq"]) == len(rec["positive"])
        assert len(rec["selected"]) >= server.buffer_size
        seen |= batch


class _StalenessAwareSelector(fl.PoolSelector):
    """A selector opting into the per-arrival staleness feed."""

    def __init__(self, num_clients, eps=0.8, seed=0):
        super().__init__(num_clients, eps, seed)
        self.seen: list = []

    def observe_staleness(self, arrivals):
        self.seen.append(arrivals)


def test_selector_staleness_feedback(tiny):
    """Selectors defining ``observe_staleness`` see every screened
    arrival's τ + verdict per flush; the round stream is untouched."""
    hook = _StalenessAwareSelector(8)
    server = _build(tiny, runtime=_STRAGGLER, selector=hook)
    plain = _build(tiny, runtime=_STRAGGLER)
    recs = [server.round() for _ in range(4)]
    for _ in range(4):
        plain.round()
    assert len(hook.seen) == len(recs)
    for batch, rec in zip(hook.seen, recs):
        assert [e["client"] for e in batch] == rec["selected"]
        admitted = [e["client"] for e in batch if e["admitted"]]
        assert sorted(admitted) == sorted(rec["positive"])
        assert all(isinstance(e["staleness"], int) and e["staleness"] >= 0
                   for e in batch)
    # pure observation: same stream as a hook-less run, bit-for-bit
    for a, b in zip(server.history, plain.history):
        assert a["selected"] == b["selected"]
        assert a["positive"] == b["positive"]
        assert a["entropy"] == b["entropy"]
    assert getattr(fl.PoolSelector(8), "observe_staleness", None) is None


def test_staleness_damping_changes_aggregation(tiny):
    """α > 0 dampens stale updates: same stream, different params."""
    damped = _build(tiny, runtime=_STRAGGLER)
    flat = _build(tiny, runtime=AsyncConfig(
        clock="straggler", latency_scale=1.0, straggler_frac=0.25,
        straggler_factor=8.0, staleness_alpha=0.0, seed=0))
    d0, f0 = damped.round(), flat.round()
    # flush 0 has zero staleness -> identical ints AND identical params
    assert d0["selected"] == f0["selected"]
    d1, f1 = damped.round(), flat.round()
    assert max(d1["staleness"]) > 0
    assert _params_digest(damped.global_params) != \
        _params_digest(flat.global_params)


def test_admission_saves_model_uplink_vs_fedavg(tiny):
    """ISSUE acceptance (test twin of BENCH_async.json): a straggler-clock
    async fedentropy run ships strictly fewer model bytes than
    round-synchronous fedavg at equal flush count."""
    data, params = tiny
    asy = _build(tiny, runtime=_STRAGGLER)
    favg = fl.build("fedavg", cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20))
    flushes = 4
    a_bytes = sum(asy.round()["comm"]["model_bytes"]
                  for _ in range(flushes))
    f_bytes = sum(favg.round()["comm"]["model_bytes"]
                  for _ in range(flushes))
    assert a_bytes < f_bytes


def test_buffer_size_knob(tiny):
    """Explicit K < |cohort| flushes early; the zero clock still screens
    whole simultaneous cohorts (tie overshoot), the straggler clock
    flushes at exactly K."""
    zero = _build(tiny, runtime=AsyncConfig(buffer_size=2))
    rec = zero.round()
    assert zero.buffer_size == 2
    assert len(rec["selected"]) == 4        # whole cohort ties at t=0
    strag = _build(tiny, runtime=AsyncConfig(
        buffer_size=2, clock="straggler", latency_scale=1.0,
        straggler_frac=0.25, straggler_factor=8.0, seed=0))
    rec = strag.round()
    assert len(rec["selected"]) == 2


def test_async_with_passthrough_judge(tiny):
    """judge="none" admits every arrival (NaN entropy) — the admission
    layer composes with any Judge via admit_candidates."""
    server = _build(tiny, "fedavg", runtime=_STRAGGLER)
    rec = server.round()
    assert rec["positive"] == rec["selected"] and rec["negative"] == []
    assert np.isnan(rec["entropy"])


# -------------------------------------------------- judge admission layer

def _skewed_soft(seed=0):
    """4 near-one-hot class signatures + sizes: class-0-heavy group."""
    rng = np.random.default_rng(seed)
    eye = np.eye(4)
    soft = 0.9 * eye[[0, 0, 0, 1]] + 0.1 * rng.dirichlet(np.ones(4), 4)
    return soft, np.full(4, 10.0)


def test_admit_empty_buffer_is_round_judgment():
    soft, sizes = _skewed_soft()
    judge = fl.MaxEntropyJudge()
    want = judge(soft, sizes)
    got = judge.admit(np.zeros((0, 4)), np.zeros((0,)), soft, sizes)
    assert got == want


def test_admit_protects_buffered_rows():
    """A buffer row the plain joint judgment would remove must stay: only
    candidates are admitted/rejected, and the rejection verdicts adapt to
    the protected group."""
    soft, sizes = _skewed_soft()
    judge = fl.MaxEntropyJudge()
    # plain joint judgment removes at least one class-0 row
    plain_a, plain_r, _ = judge(soft, sizes)
    assert plain_r
    # protect the two rows the plain sweep wanted gone -> as buffer they
    # cannot be rejected; verdicts only cover the 2 candidates
    buf = [plain_r[0], plain_a[0]]
    cand = [i for i in range(4) if i not in buf]
    a, r, ent = judge.admit(soft[buf], sizes[buf], soft[cand], sizes[cand])
    assert sorted(a + r) == [0, 1]          # candidate-relative, complete
    assert np.isfinite(ent)


def test_admit_backends_agree():
    soft, sizes = _skewed_soft()
    buf_soft, buf_sizes = soft[:2], sizes[:2]
    cand_soft, cand_sizes = soft[2:], sizes[2:]
    a_np, r_np, e_np = fl.MaxEntropyJudge("numpy").admit(
        buf_soft, buf_sizes, cand_soft, cand_sizes)
    for backend in ("xla", "pallas"):
        a, r, e = fl.MaxEntropyJudge(backend).admit(
            buf_soft, buf_sizes, cand_soft, cand_sizes)
        assert (a, r) == (a_np, r_np)
        assert e == pytest.approx(e_np, abs=1e-5)


def test_admit_candidates_fallback():
    soft, sizes = _skewed_soft()
    a, r, ent = admit_candidates(fl.PassThroughJudge(),
                                 soft[:2], sizes[:2], soft[2:], sizes[2:])
    assert a == [0, 1] and r == []
    assert np.isnan(ent)
    # relative-index mapping: a judge that rejects the last combined row
    a, r, _ = admit_candidates(fl.MaxEntropyJudge(),
                               np.zeros((0, 4)), np.zeros((0,)),
                               soft, sizes)
    assert sorted(a + r) == [0, 1, 2, 3]


def test_staleness_weights_shape_and_bounds():
    w = staleness_weights([0, 1, 3], 0.5)
    assert w[0] == 1.0 and np.all(np.diff(w) < 0)
    np.testing.assert_allclose(staleness_weights([0, 5, 9], 0.0), 1.0)
    with pytest.raises(ValueError, match=">= 0"):
        staleness_weights([-1], 0.5)


def test_arrival_clock_models():
    zero = ArrivalClock(AsyncConfig(), 8)
    assert np.all(zero.latency == 0.0)
    cfg = AsyncConfig(clock="straggler", latency_scale=2.0,
                      straggler_frac=0.25, straggler_factor=16.0, seed=3)
    clock = ArrivalClock(cfg, 8)
    again = ArrivalClock(cfg, 8)
    np.testing.assert_array_equal(clock.latency, again.latency)  # seeded
    assert np.sum(clock.latency > 2.0 * 1.5) == 2   # 25% of 8 straggle
    assert clock.arrival(0, 5.0) == 5.0 + clock.latency[0]


# ------------------------------------------------- registry error matrix

def test_engine_runtime_mismatches_error_loudly(tiny):
    with pytest.raises(ValueError, match="unknown engine 'warp'"):
        _build(tiny, engine="warp")
    with pytest.raises(ValueError, match="AsyncBufferedServer takes"):
        _build(tiny, engine="async", runtime=RuntimeConfig())
    with pytest.raises(ValueError, match="PipelinedServer takes"):
        _build(tiny, engine="pipelined", runtime=AsyncConfig())
    with pytest.raises(ValueError, match="SequentialEngine takes"):
        _build(tiny, engine="sequential", runtime=AsyncConfig())
    # direct construction is loud too, not just build()
    data, params = tiny
    with pytest.raises(ValueError, match="runtime=AsyncConfig"):
        AsyncBufferedServer(
            cnn.apply, params, data,
            fl.ServerConfig(num_clients=8, participation=0.5, seed=0),
            runtime=RuntimeConfig(),
            selector=fl.PoolSelector(8),
            strategy=fl.FedAvgStrategy(LocalSpec(epochs=1, batch_size=20)),
            judge=fl.MaxEntropyJudge(),
            aggregator=fl.WeightedAverageAggregator())


def test_async_config_routes_without_engine(tiny):
    server = _build(tiny, engine=None, runtime=AsyncConfig(buffer_size=3))
    assert isinstance(server, AsyncBufferedServer)
    assert server.buffer_size == 3
    assert fl.get("engine", "async") is AsyncBufferedServer


def test_async_refuses_group_strategies(tiny):
    with pytest.raises(ValueError, match="prepare_round"):
        _build(tiny, "fedcat+maxent")


def test_async_config_validation():
    for bad in (dict(clock="warp"), dict(buffer_size=-1),
                dict(staleness_alpha=-0.1), dict(latency_scale=-1.0),
                dict(straggler_frac=1.5), dict(straggler_factor=0.5),
                dict(concurrency=-2)):
        with pytest.raises(ValueError):
            AsyncConfig(**bad)
