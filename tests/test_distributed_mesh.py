"""Mesh-level pieces that work on the single real CPU device: sharding
rules, logical axes, param spec coverage, FedSpec ablation, serve steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED, SHAPES
from repro.core.distributed import (
    FedSpec, cache_logical_axes, chunked_head_stats, make_serve_steps,
    make_train_step, param_logical_axes,
)
from repro.models.api import build_model, input_specs, supported
from repro.optim import sgd
from repro.sharding.specs import logical_to_pspec


class FakeMesh:
    """Just enough of a Mesh for the divisibility rule engine."""
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_logical_to_pspec_divisibility():
    # kv=2 heads on 16-way model axis -> replicated
    spec = logical_to_pspec(("embed", "kv_heads"), (4096, 2 * 128), MESH)
    assert spec == P("data", "model")          # 256 divides 16
    spec = logical_to_pspec(("embed", "kv_heads"), (4096, 2 * 100), MESH)
    assert spec == P("data", None)             # 200 doesn't divide 16


def test_logical_to_pspec_prefix_fallback():
    # batch=256 on (pod,data)=32 divides fully; batch=8 falls back to the
    # longest dividing prefix (pod=2); batch=1 replicates
    s1 = logical_to_pspec(("batch",), (256,), MESH_MP)
    assert s1 == P(("pod", "data"))
    s2 = logical_to_pspec(("batch",), (8,), MESH_MP)
    assert s2 == P("pod")
    s3 = logical_to_pspec(("batch",), (1,), MESH_MP)
    assert s3 == P(None)


def test_logical_axis_not_reused_across_dims():
    spec = logical_to_pspec(("experts", "embed", "ffn"),
                            (128, 4096, 1536), MESH)
    # experts -> model; ffn would also want model but it's taken
    assert spec == P("model", "data", None)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_logical_axes_cover_all_leaves(arch):
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    axes = param_logical_axes(shape)
    flat_s = jax.tree_util.tree_leaves(shape)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_s) == len(flat_a)
    for s, a in zip(flat_s, flat_a):
        assert len(a) == len(s.shape), (s.shape, a)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_big_params_are_sharded(arch):
    """Every leaf > 8 MiB must shard on at least one mesh axis at 16x16."""
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    axes = param_logical_axes(shape)

    def check(path, sds, ax):
        nbytes = int(np.prod(sds.shape)) * sds.dtype.itemsize
        if nbytes < 8 * 2**20:
            return
        spec = logical_to_pspec(ax, sds.shape, MESH)
        assert any(p is not None for p in spec), \
            f"{path}: {sds.shape} unsharded"

    for (path, sds), ax in zip(
            jax.tree_util.tree_flatten_with_path(shape)[0],
            jax.tree_util.tree_leaves(
                axes, is_leaf=lambda x: isinstance(x, tuple))):
        check(path, sds, ax)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_build(arch, shape_name):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = supported(cfg, shape)
    if not ok:
        pytest.skip(why)
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    if shape.kind == "decode":
        assert "cache" in specs
        cache_axes = cache_logical_axes(specs["cache"])
        # structure matches
        jax.tree.map(lambda a, b: None, cache_axes,
                     jax.tree.map(lambda x: None, specs["cache"]),
                     is_leaf=lambda x: isinstance(x, tuple) or x is None)


def test_fedspec_disabled_keeps_all_clients(rng):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    opt = sgd()
    step = make_train_step(model, opt, FedSpec(num_clients=4,
                                               enabled=False))
    _, _, metrics = step(params, opt.init(params), batch)
    assert int(metrics["num_positive"]) == 4


def test_client_sizes_weight_the_loss(rng):
    """Bigger clients pull the aggregate toward their loss (Eq. 4 weights)."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    opt = sgd()
    step = make_train_step(model, opt, FedSpec(num_clients=2,
                                               enabled=False))
    _, _, m1 = step(params, opt.init(params),
                    {"tokens": toks,
                     "client_sizes": jnp.asarray([1.0, 1.0])})
    _, _, m2 = step(params, opt.init(params),
                    {"tokens": toks,
                     "client_sizes": jnp.asarray([100.0, 1.0])})
    pc = np.asarray(m1["per_client_loss"])
    expect2 = (100 * pc[0] + pc[1]) / 101
    assert float(m2["loss"]) == pytest.approx(expect2, rel=1e-4)


def test_chunked_head_stats_match_dense(rng):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 20)), jnp.int32)
    h, _ = model.hidden(params, {"tokens": toks})
    pcl, soft = chunked_head_stats(cfg, params["tok"], h, toks, 2,
                                   seq_chunk=8)
    # dense reference
    from repro.core.distributed import (
        _per_client_loss, per_client_soft_labels)
    logits, _ = model.forward(params, {"tokens": toks})
    ref_pcl = _per_client_loss(cfg, logits, toks, 2)
    ref_soft = per_client_soft_labels(logits, 2)
    np.testing.assert_allclose(np.asarray(pcl), np.asarray(ref_pcl),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(soft), np.asarray(ref_soft),
                               atol=1e-6)


def test_serve_steps_roundtrip(rng):
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill_step, decode_step = make_serve_steps(model)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    logits, cache = prefill_step(params, {"tokens": toks})
    lg, cache = decode_step(params, cache,
                            jnp.zeros((2, 1), jnp.int32))
    assert lg.shape == (2, 1, cfg.padded_vocab)
    assert int(cache["index"]) == 9


def test_kv_time_rule_shards_cache():
    """With the kv_time override, a kv-indivisible cache (kv=2 on a 16-way
    model axis) shards its time dim instead of replicating."""
    from repro.core.distributed import cache_logical_axes
    import jax
    leaf = jax.ShapeDtypeStruct((28, 128, 32768, 2, 128), jnp.bfloat16)
    axes = cache_logical_axes({"layers": {"k": leaf}})["layers"]["k"]
    assert axes == (None, "batch", "kv_time", "kv_heads", None)
    # default rules: kv_time unmapped -> replicated time dim
    spec = logical_to_pspec(axes, leaf.shape, MESH)
    assert spec == P(None, "data", None, None, None)
    # override: time -> model
    rules = dict(__import__("repro.sharding.specs",
                            fromlist=["DEFAULT_RULES"]).DEFAULT_RULES)
    rules["kv_time"] = ("model",)
    spec = logical_to_pspec(axes, leaf.shape, MESH, rules)
    assert spec == P(None, "data", "model", None, None)


def test_microbatched_step_matches_full_batch(rng):
    """Two-phase microbatched FedEntropy round (paper stage-1/stage-2 made
    literal) must produce identical masks and updates to the fused step."""
    from repro.core.distributed import make_microbatched_train_step
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    m, per, s = 4, 4, 16
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (m * per, s)), jnp.int32)}
    opt = sgd(lr=1.0, momentum=0.0)
    fed = FedSpec(num_clients=m)
    p1, _, m1 = make_train_step(model, opt, fed)(
        params, opt.init(params), batch)
    p2, _, m2 = make_microbatched_train_step(model, opt, fed, 2)(
        params, opt.init(params), batch)
    np.testing.assert_array_equal(np.asarray(m1["mask"]),
                                  np.asarray(m2["mask"]))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-6)
