"""Unit tests for core.entropy (paper Eq. 2-4).

Property-based counterparts live in test_entropy_properties.py (skipped
when the ``hypothesis`` dev extra is not installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.entropy import (
    entropy, group_entropy, group_entropy_np,
    leave_one_out_entropies, soft_label,
)


def test_entropy_uniform_is_log_c():
    for c in (2, 10, 100):
        p = jnp.full((c,), 1.0 / c)
        assert np.isclose(float(entropy(p)), np.log(c), atol=1e-6)


def test_entropy_onehot_is_zero():
    p = jnp.zeros((10,)).at[3].set(1.0)
    assert float(entropy(p)) == pytest.approx(0.0, abs=1e-6)


def test_soft_label_matches_paper_eq2(rng):
    logits = jnp.asarray(rng.normal(size=(50, 10)), jnp.float32)
    sl = soft_label(logits)
    assert sl.shape == (10,)
    assert float(jnp.sum(sl)) == pytest.approx(1.0, abs=1e-5)
    # mean of per-sample softmaxes, not softmax of mean
    per = jnp.mean(jnp.exp(logits - logits.max(-1, keepdims=True)) /
                   jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)),
                           -1, keepdims=True), axis=0)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(per), atol=1e-5)


def test_group_entropy_matches_numpy(rng):
    m, c = 12, 20
    p = rng.dirichlet(np.full(c, 0.5), size=m)
    sizes = rng.integers(1, 100, m).astype(np.float64)
    mask = (rng.random(m) > 0.5).astype(np.float64)
    mask[0] = 1.0
    ours = float(group_entropy(jnp.asarray(p, jnp.float32),
                               jnp.asarray(sizes, jnp.float32),
                               jnp.asarray(mask, jnp.float32)))
    ref = group_entropy_np(p, sizes, mask)
    assert ours == pytest.approx(ref, abs=1e-5)


def test_leave_one_out_matches_bruteforce(rng):
    m, c = 10, 8
    p = rng.dirichlet(np.full(c, 0.3), size=m)
    sizes = rng.integers(1, 100, m).astype(np.float64)
    mask = np.ones(m)
    loo = np.asarray(leave_one_out_entropies(
        jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(mask, jnp.float32)))
    for k in range(m):
        trial = mask.copy()
        trial[k] = 0
        ref = group_entropy_np(p, sizes, trial)
        assert loo[k] == pytest.approx(ref, abs=1e-4)


def test_leave_one_out_inactive_is_noop(rng):
    m, c = 6, 5
    p = rng.dirichlet(np.full(c, 0.3), size=m)
    sizes = np.ones(m)
    mask = np.ones(m)
    mask[2] = 0.0
    loo = np.asarray(leave_one_out_entropies(
        jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32),
        jnp.asarray(mask, jnp.float32)))
    cur = group_entropy_np(p, sizes, mask)
    assert loo[2] == pytest.approx(cur, abs=1e-5)


def test_leave_one_out_never_empties():
    p = jnp.asarray([[0.5, 0.5]], jnp.float32)
    loo = leave_one_out_entropies(p, jnp.ones((1,)), jnp.ones((1,)))
    assert float(loo[0]) == -1.0
