"""End-to-end FL integration: the paper's qualitative claims at test scale.

These are the fast versions of the benchmark tables: on strongly non-IID
synthetic data (case 1: one label per client), FedEntropy's judgment +
pools must not hurt — and, with the seeds fixed here, must beat — plain
FedAvg, while uploading strictly fewer model bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulator import (
    FedEntropyTrainer, FLConfig, total_uplink_bytes,
)
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 10


@pytest.fixture(scope="module")
def setup():
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=4, train_per_class=100, test_per_class=25, hw=16,
        noise=0.4, seed=3)
    parts = partition("case1", ytr, 12, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=25)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params, (jnp.asarray(xte), jnp.asarray(yte))


def _run(setup, use_judgment, use_pools=True, seed=0):
    data, params, test = setup
    tr = FedEntropyTrainer(
        cnn.apply, params, data,
        FLConfig(num_clients=12, participation=0.34,
                 use_judgment=use_judgment, use_pools=use_pools, seed=seed),
        LocalSpec(epochs=2, batch_size=25, lr=0.05))
    for _ in range(ROUNDS):
        tr.round()
    acc = tr.evaluate(*test)["accuracy"]
    return acc, total_uplink_bytes(tr.history), tr


def test_fedentropy_not_worse_than_fedavg(setup):
    acc_fe, bytes_fe, tr = _run(setup, use_judgment=True)
    acc_avg, bytes_avg, _ = _run(setup, use_judgment=False)
    # accuracy: no degradation beyond noise; with these seeds it wins
    assert acc_fe >= acc_avg - 0.05
    # communication: judgment must have filtered at least one model upload
    assert bytes_fe < bytes_avg
    # pools actually got populated
    assert tr.pools.stats()["negative"] >= 0


def test_judgment_filters_redundant_clients(setup):
    """In case-1 non-IID, selecting several same-label clients must trigger
    removals in at least some rounds."""
    _, _, tr = _run(setup, use_judgment=True, seed=1)
    removed = sum(len(h["negative"]) for h in tr.history)
    assert removed > 0


def test_entropy_of_positives_not_below_initial(setup):
    _, _, tr = _run(setup, use_judgment=True, seed=2)
    for h in tr.history:
        assert not np.isnan(h["entropy"])


def test_distributed_step_equals_weighted_grad(rng):
    """Gradient-level FedEntropy (mesh formulation) == masked weighted
    per-client gradients, verified against explicit per-client grads."""
    from repro.configs import ARCHS
    from repro.core.distributed import FedSpec, make_train_step
    from repro.models.api import build_model
    from repro.optim import sgd

    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    m, per, s = 4, 2, 16
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (m * per, s)), jnp.int32)
    batch = {"tokens": tokens}

    fed = FedSpec(num_clients=m)
    opt = sgd(lr=1.0, momentum=0.0)      # step == -grad
    step = make_train_step(model, opt, fed)
    new_params, _, metrics = step(params, opt.init(params), batch)
    mask = np.asarray(metrics["mask"])

    # explicit per-client grads of the same loss
    def client_loss(p, client):
        lg, aux = model.forward(
            p, {"tokens": tokens[client * per:(client + 1) * per]})
        from repro.models.transformer import lm_loss
        return lm_loss(cfg, lg, tokens[client * per:(client + 1) * per]) \
            + cfg.router_aux_weight * aux

    grads = [jax.grad(client_loss)(params, c) for c in range(m)]
    w = mask / mask.sum()
    for path_leaf, new_leaf, old_leaf in zip(
            jax.tree_util.tree_flatten_with_path(grads[0])[0],
            jax.tree.leaves(new_params), jax.tree.leaves(params)):
        path, g0 = path_leaf
        manual = sum(w[c] * np.asarray(
            jax.tree.leaves(grads[c])[  # same leaf order
                jax.tree.leaves(grads[0]).index(g0)])
            for c in range(m))
        applied = np.asarray(old_leaf) - np.asarray(new_leaf)
        np.testing.assert_allclose(applied, manual, atol=5e-4,
                                   err_msg=str(path))
        break  # first leaf suffices (full sweep is slow on CPU)
