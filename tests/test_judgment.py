"""Algorithm 1 (maximum entropy judgment): JAX while_loop vs numpy oracle,
plus the paper-level invariants.

Property-based counterparts live in test_judgment_properties.py (skipped
when the ``hypothesis`` dev extra is not installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.entropy import group_entropy_np
from repro.core.judgment import judge, judge_np


def _case(m, c, seed, concentration=0.3):
    r = np.random.default_rng(seed)
    p = r.dirichlet(np.full(c, concentration), size=m)
    sizes = r.integers(10, 500, m).astype(np.float64)
    return p, sizes


def test_oracle_monotone_entropy():
    """Each greedy removal strictly increases the group entropy."""
    p, sizes = _case(12, 10, 0)
    A, R, ent = judge_np(p, sizes)
    # replay removals, checking monotonicity
    mask = np.ones(12)
    prev = group_entropy_np(p, sizes, mask)
    for k in R:
        mask[k] = 0
        cur = group_entropy_np(p, sizes, mask)
        assert cur > prev
        prev = cur
    assert ent == pytest.approx(prev, abs=1e-9)


def test_oracle_local_optimum():
    """On termination no single removal improves entropy (Alg.1 line 13)."""
    p, sizes = _case(12, 10, 1)
    A, R, ent = judge_np(p, sizes)
    mask = np.zeros(12)
    mask[A] = 1
    for k in A:
        trial = mask.copy()
        trial[k] = 0
        if len(A) > 1:
            assert group_entropy_np(p, sizes, trial) <= ent + 1e-6


def test_jax_matches_oracle_many_seeds():
    for seed in range(25):
        m = 5 + seed % 10
        p, sizes = _case(m, 10, seed)
        A, R, ent = judge_np(p, sizes)
        res = judge(jnp.asarray(p, jnp.float32),
                    jnp.asarray(sizes, jnp.float32))
        mask_ref = np.zeros(m)
        mask_ref[A] = 1
        np.testing.assert_array_equal(np.asarray(res.mask), mask_ref,
                                      err_msg=f"seed {seed}")
        assert float(res.entropy) == pytest.approx(ent, abs=1e-4)
        assert int(res.num_removed) == len(R)


def test_never_empty():
    """Extremely biased one-hot devices: set is never emptied."""
    m, c = 6, 6
    p = np.eye(c)[:m] * 0.999 + 0.001 / c
    sizes = np.ones(m)
    res = judge(jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32))
    assert float(jnp.sum(res.mask)) >= 1.0
    A, R, _ = judge_np(p, sizes)
    assert len(A) >= 1


def test_respects_active_mask():
    p, sizes = _case(8, 10, 3)
    active = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float64)
    res = judge(jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32),
                active=jnp.asarray(active, jnp.float32))
    # inactive devices can never be positive
    assert np.all(np.asarray(res.mask)[4:] == 0)
    A, R, _ = judge_np(p, sizes, active=active)
    mask_ref = np.zeros(8)
    mask_ref[A] = 1
    np.testing.assert_array_equal(np.asarray(res.mask), mask_ref)


def test_uniform_devices_all_kept():
    """Identical (already-uniform) soft labels: nothing to remove."""
    m, c = 8, 10
    p = np.full((m, c), 1.0 / c)
    res = judge(jnp.asarray(p, jnp.float32), jnp.ones((m,), jnp.float32))
    assert float(jnp.sum(res.mask)) == m
    assert int(res.num_removed) == 0


def test_complementary_beats_redundant():
    """A device complementing the label mix is kept over one amplifying
    the majority — the paper's core selection behaviour."""
    c = 4
    maj = np.array([0.85, 0.05, 0.05, 0.05])
    comp = np.array([0.02, 0.32, 0.33, 0.33])
    p = np.stack([maj, maj, maj, comp])
    res = judge(jnp.asarray(p, jnp.float32), jnp.ones((4,), jnp.float32))
    mask = np.asarray(res.mask)
    assert mask[3] == 1.0          # the complementary device survives
    assert mask.sum() < 4          # at least one majority device is dropped


def test_pallas_backend_matches_xla():
    """judge(backend="pallas") routes through the entropy_judge kernel and
    must agree with the jnp sweep (and thus the numpy oracle)."""
    for seed in range(5):
        m = 6 + seed
        p, sizes = _case(m, 12, seed)
        r1 = judge(jnp.asarray(p, jnp.float32),
                   jnp.asarray(sizes, jnp.float32))
        r2 = judge(jnp.asarray(p, jnp.float32),
                   jnp.asarray(sizes, jnp.float32), backend="pallas")
        np.testing.assert_array_equal(np.asarray(r1.mask),
                                      np.asarray(r2.mask))
        assert float(jnp.abs(r1.entropy - r2.entropy)) < 1e-4


def test_budgeted_judgment_respects_budget_and_near_optimal():
    """Beyond-paper forward-greedy selection: exactly B devices; entropy
    within tolerance of the exhaustive optimum at small M."""
    import itertools
    from repro.core.judgment import judge_budgeted
    r = np.random.default_rng(0)
    for seed in range(4):
        m, c, b = 8, 6, 3
        p = np.random.default_rng(seed).dirichlet(np.full(c, 0.3), size=m)
        sizes = np.random.default_rng(seed + 1).integers(
            10, 200, m).astype(np.float64)
        res = judge_budgeted(jnp.asarray(p, jnp.float32),
                             jnp.asarray(sizes, jnp.float32), b)
        mask = np.asarray(res.mask)
        assert mask.sum() == b
        best = max(
            (group_entropy_np(p, sizes,
                              np.isin(np.arange(m), comb).astype(float))
             for comb in itertools.combinations(range(m), b)))
        assert float(res.entropy) >= best - 0.05


def test_budgeted_judgment_respects_active():
    from repro.core.judgment import judge_budgeted
    r = np.random.default_rng(3)
    p = r.dirichlet(np.full(5, 0.4), size=6)
    active = np.array([1, 1, 1, 0, 0, 0], np.float64)
    res = judge_budgeted(jnp.asarray(p, jnp.float32),
                         jnp.ones((6,), jnp.float32), 2,
                         active=jnp.asarray(active, jnp.float32))
    assert np.all(np.asarray(res.mask)[3:] == 0)
    assert np.asarray(res.mask).sum() == 2
