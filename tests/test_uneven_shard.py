"""The padded-shard data plane for uneven client counts (N % mesh != 0).

The paper's N=100 is not divisible by any realistic accelerator count;
``ClientCorpus.shard`` pads the client axis with zero rows up to the
next mesh multiple and shards ``P("clients")`` instead of silently
replicating. Control-plane surfaces (``num_clients``/``sizes``/
``label_histograms``/``as_numpy``) keep reporting the real N, global
client ids map through the padded layout unchanged, and the golden
verdict histories stay bit-for-bit across Server / PipelinedServer with
speculation on and off.

Placement needs real devices: the multi-device tests here run under the
CI job that forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(and skip on the default single-device suite), while a subprocess smoke
exercises the core layout claims from the single-device suite too.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.corpus import ClientCorpus, pad_client_axis
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig, make_client_mesh
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "uneven_history.json")
PAPER_N, CLASSES = 100, 10

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs a multi-device mesh (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def paper():
    """Identical to the setup tests/golden/record_uneven.py recorded."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=CLASSES, train_per_class=2 * PAPER_N, test_per_class=10,
        hw=16, noise=0.9, seed=0)
    parts = partition("case1", ytr, PAPER_N, CLASSES, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=10)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16,
                      num_classes=CLASSES)
    return data, params


# ------------------------------------------------------- padding (any mesh)

def test_pad_client_axis_zero_rows():
    """Pad rows are zeros in every array (zero w => provably inert
    clients), real rows and dtypes untouched, identity at pad=0."""
    arrays = {"x": jnp.arange(24, dtype=jnp.uint8).reshape(4, 6),
              "y": jnp.ones((4, 6), jnp.int32),
              "w": jnp.ones((4, 6), jnp.float32)}
    padded = pad_client_axis(arrays, 3)
    for k, v in padded.items():
        assert v.shape[0] == 7 and v.dtype == arrays[k].dtype
        np.testing.assert_array_equal(np.asarray(v[:4]),
                                      np.asarray(arrays[k]))
        np.testing.assert_array_equal(np.asarray(v[4:]), 0)
    same = pad_client_axis(arrays, 0)
    for k in arrays:
        assert same[k] is arrays[k]


# --------------------------------------------------- placement (multi-dev)

@multidevice
def test_padded_shard_layout_real_n_control_plane(paper):
    """ISSUE acceptance: on an 8-device mesh with N=100 the corpus shards
    P("clients") with padded leading axis 104 (never replicates), the
    busiest device holds ~1/8 of the padded bytes (13/100 of the
    replicated total), and every control-plane stat reports the real N."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    data, _ = paper
    corpus = ClientCorpus.from_stacked(dict(data))
    unpadded_nbytes = corpus.nbytes
    mesh = make_client_mesh()
    ndev = mesh.shape["clients"]
    assert corpus.shard(mesh) is corpus
    corpus.shard(mesh)                                   # idempotent
    padded_n = PAPER_N + (-PAPER_N) % ndev
    assert corpus.padded_num_clients == padded_n
    assert corpus.num_clients == PAPER_N                 # real N
    for v in corpus.values():
        assert v.sharding.spec == P("clients"), v.sharding  # no replication
    # per-device resident bytes shrink vs replication: a replicated
    # layout holds the full corpus on every device
    rep = jax.device_put(np.asarray(data["x"]), NamedSharding(mesh, P()))
    rep_dev_bytes = next(iter(rep.addressable_shards)).data.size \
        * rep.dtype.itemsize
    assert rep_dev_bytes == data["x"].nbytes
    assert corpus.device_nbytes() * ndev <= corpus.nbytes + ndev
    assert corpus.device_nbytes() < unpadded_nbytes / (ndev / 2)
    # control plane: real N everywhere, pad rows invisible
    assert corpus.client_valid.sum() == PAPER_N
    assert not corpus.client_valid[PAPER_N:].any()
    assert corpus.sizes().shape == (PAPER_N,)
    assert (corpus.sizes() > 0).all()
    assert corpus.label_histograms().shape[0] == PAPER_N
    assert corpus.label_entropy().shape == (PAPER_N,)
    assert corpus.as_numpy()["y"].shape[0] == PAPER_N
    # signature keys on the padded layout (compiled-program cache safety)
    fresh = ClientCorpus.from_stacked(dict(data))
    assert corpus.signature() != fresh.signature()


@multidevice
def test_padded_cohort_matches_host_reference(paper):
    """Gathers of global client ids through the padded layout equal the
    host-slice reference bit-for-bit, and stay transfer-free."""
    data, _ = paper
    corpus = ClientCorpus.from_stacked(dict(data))
    corpus.shard(make_client_mesh())
    idx = np.array([0, 7, 99, 42, 13, 98])        # spans shard boundaries
    got = corpus.cohort(idx)
    for k in data:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(data[k])[idx])
    # device-resident idx (replicated over the corpus mesh): zero host
    # bytes cross the boundary during the gather
    didx = corpus.put_index(idx.astype(np.int32))
    corpus.cohort(didx)                           # compile outside guard
    with jax.transfer_guard("disallow"):
        got2 = corpus.cohort(didx)
    for k in data:
        np.testing.assert_array_equal(np.asarray(got2[k]),
                                      np.asarray(data[k])[idx])


@multidevice
def test_reshard_onto_different_mesh_rederives_pad(paper):
    """Re-sharding onto a mesh of another size re-pads from the real rows
    (no pad-on-pad), and cohorts still match the host reference."""
    data, _ = paper
    corpus = ClientCorpus.from_stacked(dict(data))
    devs = jax.devices()
    corpus.shard(make_client_mesh(devs[:3]))      # 100 -> 102
    assert corpus.padded_num_clients == 102
    corpus.shard(make_client_mesh(devs))          # 100 -> 104, from real N
    assert corpus.padded_num_clients == PAPER_N + (-PAPER_N) % len(devs)
    assert corpus.num_clients == PAPER_N
    idx = np.array([3, 57, 99])
    got = corpus.cohort(idx)
    for k in data:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(data[k])[idx])


# ------------------------------------------------ golden round equivalence

def _hist_ints(h):
    return [(r["selected"], r["positive"], r["negative"],
             r["comm"]["total_bytes"]) for r in h]


@pytest.mark.parametrize("variant,comp", [
    ("fedentropy", "fedentropy"),
    ("fedcat_maxent", "fedcat+maxent"),
    ("fedentropy_queue", "fedentropy+queue"),
])
@multidevice
def test_uneven_golden_histories_all_engines(paper, variant, comp):
    """ISSUE acceptance: at N=100 on the uneven mesh, Server and
    PipelinedServer (speculation on AND off) reproduce the recorded
    verdict histories bit-for-bit. Integer fields (selection, verdicts,
    comm bytes) are exact everywhere; entropy floats cross compiled
    program shapes (a sharded fan-out vmaps a different batch size than
    the single-device recorder), where CPU XLA is not bitwise-stable, so
    they carry a float tolerance — while spec-on vs spec-off run the same
    programs and must agree on everything, entropy bits included."""
    with open(GOLDEN) as f:
        golden = json.load(f)[variant]
    data, params = paper
    cfg = fl.ServerConfig(num_clients=PAPER_N, participation=0.1, seed=0,
                          group_size=2)
    local = LocalSpec(epochs=1, batch_size=10)
    engines = {
        "seq": fl.build(comp, cnn.apply, params, data, cfg, local),
        "off": fl.build(comp, cnn.apply, params, data, cfg, local,
                        engine="pipelined", runtime=RuntimeConfig()),
        "spec": fl.build(comp, cnn.apply, params, data, cfg, local,
                         engine="pipelined",
                         runtime=RuntimeConfig(speculate=True)),
    }
    rounds = len(golden["history"])
    for server in engines.values():
        for _ in range(rounds):
            server.round()
    for name, server in engines.items():
        assert _hist_ints(server.history) == [
            (g["selected"], g["positive"], g["negative"], g["total_bytes"])
            for g in golden["history"]], name
        for rec, g in zip(server.history, golden["history"]):
            assert rec["entropy"] == pytest.approx(float(g["entropy"]),
                                                   abs=1e-6), name
    # the sharded engines really ran the padded layout
    for name in ("off", "spec"):
        corpus = engines[name].corpus
        assert corpus.padded_num_clients > PAPER_N
        from jax.sharding import PartitionSpec as P
        assert all(v.sharding.spec == P("clients")
                   for v in corpus.values())
    # spec-on and spec-off: same compiled programs, bit-identical history
    off, spec = engines["off"].history, engines["spec"].history
    for a, b in zip(off, spec):
        assert a["selected"] == b["selected"]
        assert a["positive"] == b["positive"]
        assert a["negative"] == b["negative"]
        assert a["entropy"] == b["entropy"]               # exact bits


# ------------------------------------------------- single-device subprocess

_SMOKE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.data.corpus import ClientCorpus
from repro.fl.runtime import make_client_mesh
assert len(jax.devices()) == 8, jax.devices()
n, s = 10, 6                                  # 10 % 8 != 0 -> pad to 16
rng = np.random.default_rng(0)
data = {"x": rng.normal(size=(n, s, 3)).astype(np.float32),
        "y": rng.integers(0, 4, size=(n, s)).astype(np.int32),
        "w": np.ones((n, s), np.float32)}
corpus = ClientCorpus.from_stacked(data)
full = corpus.nbytes
mesh = make_client_mesh()
corpus.shard(mesh)
assert corpus.padded_num_clients == 16 and corpus.num_clients == n
assert all(v.sharding.spec == P("clients") for v in corpus.values())
assert corpus.device_nbytes() * 4 < full      # 2/16 rows per device
assert corpus.sizes().shape == (n,)
idx = np.array([0, 9, 3])
got = corpus.cohort(idx)
for k in data:
    np.testing.assert_array_equal(np.asarray(got[k]), data[k][idx])
didx = corpus.put_index(idx.astype(np.int32))
corpus.cohort(didx)
with jax.transfer_guard("disallow"):
    jax.block_until_ready(corpus.cohort(didx)["x"])
print("UNEVEN-SMOKE-OK")
"""


def test_padded_shard_smoke_under_forced_devices():
    """The single-device tier-1 suite still exercises the real placement:
    a subprocess forces 8 host devices and asserts the padded-shard
    claims (P("clients") layout, per-device bytes shrink, host-reference
    gathers, transfer-free device-idx path)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SMOKE], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "UNEVEN-SMOKE-OK" in out.stdout
