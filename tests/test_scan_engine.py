"""The R-round ``lax.scan`` engine: golden and live-sequential history
equivalence for R in {1, 4}, forced-misspeculation truncation + oracle
replay, eligibility fallback for stateful selectors, device-mode
selection determinism, block-granular parameter semantics, and the
engine/runtime registry error matrix."""
import json
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig, ScanConfig, ScanServer
from repro.models import cnn

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "seed_history.json")

# same tolerance policy as test_runtime_engine.py: selection/verdict ints
# exact everywhere, entropy floats exact on the single device the goldens
# were recorded on, tolerant under the forced multi-device CI mesh
_SINGLE_DEVICE = len(jax.devices()) == 1
ENT_ATOL = 1e-9 if _SINGLE_DEVICE else 1e-6
DIGEST_REL = 1e-7 if _SINGLE_DEVICE else 1e-5


@pytest.fixture(scope="module")
def tiny():
    """Identical to the setup the golden histories were recorded with."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _build(tiny, name="fedavg", runtime=None, engine="scan", **overrides):
    data, params = tiny
    return fl.build(name, cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20),
                    engine=engine, runtime=runtime, **overrides)


def _params_digest(params) -> float:
    return float(sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(params)))


def _assert_records_equal(got, want):
    """Live engine-vs-engine comparison: everything int exact, entropy to
    the device tolerance."""
    assert len(got) == len(want)
    for g, w in zip(got, want):
        for k in ("round", "selected", "positive", "negative"):
            assert g[k] == w[k]
        assert g["comm"] == w["comm"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=ENT_ATOL)


# ----------------------------------------------------- golden equivalence

@pytest.mark.parametrize("R", [1, 4])
def test_scan_matches_golden_fedavg(tiny, R):
    """ISSUE acceptance: ScanServer histories are bit-for-bit the
    sequential ``Server``'s on the golden seed for R in {1, 4}."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedavg_uniform"]
    server = _build(tiny, runtime=ScanConfig(rounds_per_scan=R))
    assert isinstance(server, ScanServer)
    assert server.scan_rounds() == R
    n = len(golden["history"])
    for _ in range(n):
        server.round()
    assert len(server.history) == n
    for g, w in zip(server.history, golden["history"]):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        assert g["comm"]["total_bytes"] == w["total_bytes"]
        ent = float(w["entropy"])
        if np.isnan(ent):
            assert np.isnan(g["entropy"])
        else:
            assert g["entropy"] == pytest.approx(ent, abs=ENT_ATOL)
        if R > 1:     # R=1 is the plain sequential round: no spec flags
            assert g["spec_hit"] is True
    if R == 1:
        # params advance block-at-a-time; only the R=1 run is at the
        # same round the golden digest was recorded at (R=4 has already
        # computed rounds 5..7 of the second block)
        assert _params_digest(server.global_params) == pytest.approx(
            float(golden["params_digest"]), rel=DIGEST_REL)


def test_scan_matches_live_sequential_fedentropy(tiny):
    """fedentropy with the Fig. 3b uniform selector (judgment, no pools):
    8 rounds = two full R=4 blocks against a live sequential Server —
    histories equal and end-of-block params equal."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20),
                   selector="uniform")
    scan = _build(tiny, "fedentropy", runtime=ScanConfig(rounds_per_scan=4),
                  selector="uniform")
    assert scan.scan_rounds() == 4
    for _ in range(8):
        seq.round()
        scan.round()
    _assert_records_equal(scan.history, seq.history)
    assert all(r["spec_hit"] for r in scan.history)
    assert _params_digest(scan.global_params) == pytest.approx(
        _params_digest(seq.global_params), rel=DIGEST_REL)


def test_scan_pallas_judge_backend(tiny):
    """spec_backend="pallas" speculates in-scan through the class-tiled
    entropy_judge_sweep kernel (interpret mode on CPU)."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedavg_uniform"]
    server = _build(tiny, runtime=ScanConfig(rounds_per_scan=4,
                                             spec_backend="pallas"))
    for _ in range(4):
        server.round()
    for g, w in zip(server.history, golden["history"][:4]):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]


# -------------------------------------------------- misspeculation replay

class _WrongScanJudge(fl.MaxEntropyJudge):
    """Oracle = real maxent; traced form always admits everyone, so any
    round with a rejection misspeculates and must truncate the block."""

    def traced(self):
        return fl.PassThroughJudge().traced()


def test_scan_forced_mismatch_truncates_and_replays(tiny):
    """A wrong in-scan verdict must be discarded: the mismatched round
    re-runs eagerly from the float64 oracle, the remaining pre-drawn
    cohorts re-scan, and the recorded history still equals the sequential
    Server's (whose oracle is the same maxent judge) bit-for-bit."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20),
                   selector="uniform")
    scan = _build(tiny, "fedentropy", runtime=ScanConfig(rounds_per_scan=4),
                  selector="uniform", judge=_WrongScanJudge())
    for _ in range(8):
        seq.round()
        scan.round()
    _assert_records_equal(scan.history, seq.history)
    assert _params_digest(scan.global_params) == pytest.approx(
        _params_digest(seq.global_params), rel=DIGEST_REL)
    # the sequential run rejects someone in these 8 rounds, so at least
    # one scan round misspeculated (spec_hit=False) and at least one
    # later confirmed round came from a truncated re-scan (redispatched)
    assert any(r["negative"] for r in seq.history)
    assert any(not r["spec_hit"] for r in scan.history)
    assert any(r["redispatched"] for r in scan.history)
    for r in scan.history:
        if not r["spec_hit"]:
            assert r["negative"], "only rejection rounds can misspeculate"


# ------------------------------------------------- remat memory mode

@pytest.mark.parametrize("R", [1, 4])
def test_scan_remat_matches_stack_and_sequential(tiny, R):
    """ISSUE acceptance: params_mode="remat" histories are bit-for-bit
    both params_mode="stack" and the sequential Server under a
    forced-mismatch judge — the rematerialized rewind point must be the
    exact params the stacked ys would have held."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20),
                   selector="uniform")
    engines = {}
    for mode in ("stack", "remat"):
        engines[mode] = _build(
            tiny, "fedentropy",
            runtime=ScanConfig(rounds_per_scan=R, params_mode=mode),
            selector="uniform", judge=_WrongScanJudge())
        assert engines[mode].scan_rounds() == R
    for _ in range(8):
        seq.round()
        for s in engines.values():
            s.round()
    for s in engines.values():
        _assert_records_equal(s.history, seq.history)
    if R > 1:
        # the forced-mismatch judge really exercised the rewind path
        assert any(not r["spec_hit"] for r in engines["remat"].history)
    # stack and remat must agree bitwise, not merely to tolerance
    for a, b in zip(jax.tree.leaves(engines["stack"].global_params),
                    jax.tree.leaves(engines["remat"].global_params)):
        assert bool(jnp.all(a == b))
    assert _params_digest(engines["remat"].global_params) == pytest.approx(
        _params_digest(seq.global_params), rel=DIGEST_REL)


def test_scan_remat_ys_carry_no_params(tiny):
    """The remat block's stacked ys hold only O(cohort x classes) verdict
    inputs — no params leaf — so device memory per block is independent
    of the model size (stack mode pins R post-round param copies)."""
    stack = _build(tiny, runtime=ScanConfig(rounds_per_scan=4,
                                            params_mode="stack"))
    remat = _build(tiny, runtime=ScanConfig(rounds_per_scan=4,
                                            params_mode="remat"))
    s_shapes = stack.block_ys_shapes(4)
    r_shapes = remat.block_ys_shapes(4)
    assert "params" in s_shapes
    assert "params" not in r_shapes
    from repro.core.aggregation import tree_bytes
    params_nbytes = tree_bytes(stack.global_params)
    assert remat.stacked_ys_nbytes(4) < params_nbytes
    assert (stack.stacked_ys_nbytes(4) - remat.stacked_ys_nbytes(4)
            == 4 * params_nbytes)


# ------------------------------------------------------ traced pool carry

def test_scan_pools_traced_folds_bit_for_bit(tiny):
    """The paper's fedentropy composition with selector="pools-traced"
    folds R=4 (no fallback) and reproduces the sequential Server's
    history and params exactly — including through a forced mismatch,
    which must truncate and rebuild the pool carry."""
    data, params = tiny
    seq = fl.build("fedentropy", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20),
                   selector="pools-traced")
    for _ in range(8):
        seq.round()
    for mode in ("stack", "remat"):
        for judge in (None, _WrongScanJudge()):
            scan = _build(
                tiny, "fedentropy",
                runtime=ScanConfig(rounds_per_scan=4, params_mode=mode),
                selector="pools-traced",
                **({} if judge is None else {"judge": judge}))
            assert scan.scan_rounds() == 4
            assert scan.stats()["fallback_reasons"] == []
            assert scan.stats()["pool_fold"] is True
            for _ in range(8):
                scan.round()
            _assert_records_equal(scan.history, seq.history)
            assert _params_digest(scan.global_params) == pytest.approx(
                _params_digest(seq.global_params), rel=DIGEST_REL)
            if judge is not None:
                assert any(not r["spec_hit"] for r in scan.history)


def test_scan_pools_traced_matches_composition_alias(tiny):
    """The "fedentropy-traced" composition is fedentropy with the traced
    pools — same stream as the explicit selector override."""
    a = _build(tiny, "fedentropy-traced",
               runtime=ScanConfig(rounds_per_scan=4))
    b = _build(tiny, "fedentropy", runtime=ScanConfig(rounds_per_scan=4),
               selector="pools-traced")
    assert a.scan_rounds() == b.scan_rounds() == 4
    for _ in range(8):
        a.round()
        b.round()
    _assert_records_equal(a.history, b.history)


# ---------------------------------------------------- eligibility fallback

def test_scan_pools_falls_back_to_sequential(tiny, caplog):
    """Verdict-coupled selectors (pools) cannot fold: R collapses to 1
    with one loud log and the composition still reproduces its golden."""
    with open(GOLDEN) as f:
        golden = json.load(f)["fedentropy"]
    server = _build(tiny, "fedentropy",
                    runtime=ScanConfig(rounds_per_scan=4))
    with caplog.at_level(logging.WARNING,
                         logger="repro.fl.runtime.scan_engine"):
        assert server.scan_rounds() == 1
    assert any("falling back" in r.message for r in caplog.records)
    for _ in range(len(golden["history"])):
        server.round()
    for g, w in zip(server.history, golden["history"]):
        assert g["selected"] == w["selected"]
        assert g["positive"] == w["positive"]
        assert g["negative"] == w["negative"]
        ent = float(w["entropy"])
        if not np.isnan(ent):
            assert g["entropy"] == pytest.approx(ent, abs=ENT_ATOL)
    assert _params_digest(server.global_params) == pytest.approx(
        float(golden["params_digest"]), rel=DIGEST_REL)


def test_scan_stateful_strategy_falls_back(tiny):
    """SCAFFOLD carries cross-round control variates: no fold."""
    server = _build(tiny, "scaffold",
                    runtime=ScanConfig(rounds_per_scan=4))
    assert server.scan_rounds() == 1


@pytest.mark.parametrize("name,code,component", [
    ("fedentropy", "verdict-coupled-selector", "PoolSelector"),
    ("fedentropy+queue", "verdict-coupled-selector", "QueueSelector"),
    ("scaffold", "stateful-strategy", "ScaffoldStrategy"),
    ("fedcat", "group-dispatch", "CatChainStrategy"),
])
def test_scan_fallback_reason_codes(tiny, name, code, component):
    """Every non-foldable composition reports WHY it fell back, machine
    readably: ``fallback_reasons`` dicts with a stable ``code``, the
    offending component class, and prose detail — mirrored in
    ``stats()`` and, per round, on the history record."""
    server = _build(tiny, name, runtime=ScanConfig(rounds_per_scan=4))
    assert server.scan_rounds() == 1
    reasons = server.fallback_reasons
    assert reasons, name
    by_code = {r["code"]: r for r in reasons}
    assert code in by_code
    assert by_code[code]["component"] == component
    assert by_code[code]["detail"]
    assert server.stats()["fallback_reasons"] == reasons
    rec = server.round()
    assert rec["scan_fallback"] == [r["code"] for r in reasons]
    assert code in rec["scan_fallback"]


def test_scan_foldable_composition_reports_no_reasons(tiny):
    """Foldable compositions report an empty reason list — and their
    (folded) records carry no ``scan_fallback`` key."""
    server = _build(tiny, "fedavg",
                    runtime=ScanConfig(rounds_per_scan=4))
    assert server.scan_rounds() == 4
    assert server.fallback_reasons == []
    assert "scan_fallback" not in server.round()


def test_scan_config_rejects_bad_params_mode():
    with pytest.raises(ValueError, match="params_mode"):
        ScanConfig(rounds_per_scan=4, params_mode="checkpoint")


# --------------------------------------------------- device-mode selection

def test_scan_device_selection_deterministic(tiny):
    """selection="device" draws cohorts on device from a carried PRNG key:
    not golden-comparable, but reproducible per seed."""
    cfg = ScanConfig(rounds_per_scan=4, selection="device")
    a = _build(tiny, runtime=cfg)
    b = _build(tiny, runtime=cfg)
    for _ in range(8):
        a.round()
        b.round()
    _assert_records_equal(a.history, b.history)
    assert _params_digest(a.global_params) == pytest.approx(
        _params_digest(b.global_params), rel=1e-12)
    for rec in a.history:
        assert len(rec["selected"]) == 4
        assert len(set(rec["selected"])) == 4          # replace=False
        assert all(0 <= c < 8 for c in rec["selected"])


# ------------------------------------------------------- block semantics

def test_scan_params_advance_block_at_a_time(tiny):
    """One ``round()`` pops one record but the model has already advanced
    through the whole R-round block (the documented trade-off)."""
    data, params = tiny
    seq = fl.build("fedavg", cnn.apply, params, data,
                   fl.ServerConfig(num_clients=8, participation=0.5,
                                   seed=0),
                   LocalSpec(epochs=1, batch_size=20))
    scan = _build(tiny, runtime=ScanConfig(rounds_per_scan=4))
    scan.round()
    assert len(scan.history) == 1
    for _ in range(4):
        seq.round()
    assert _params_digest(scan.global_params) == pytest.approx(
        _params_digest(seq.global_params), rel=DIGEST_REL)


# ------------------------------------------------------- registry matrix

def test_scan_config_validation():
    with pytest.raises(ValueError, match="rounds_per_scan"):
        ScanConfig(rounds_per_scan=0)
    with pytest.raises(ValueError, match="selection"):
        ScanConfig(selection="bogus")


def test_scan_config_routes_without_engine(tiny):
    server = _build(tiny, engine=None, runtime=ScanConfig())
    assert isinstance(server, ScanServer)


def test_engine_runtime_mismatches_error_loudly(tiny):
    with pytest.raises(ValueError, match="ScanConfig"):
        _build(tiny, engine="scan", runtime=RuntimeConfig())
    with pytest.raises(ValueError, match="RuntimeConfig"):
        _build(tiny, engine="pipelined", runtime=ScanConfig())
    with pytest.raises(ValueError, match="ScanConfig"):
        _build(tiny, engine=ScanServer,
               runtime=RuntimeConfig(speculate=True))
