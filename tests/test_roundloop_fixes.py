"""The round-loop bugfix sweep: low-precision ``masked_mean_tree``
accumulation, the fused (M, P) aggregation path, ``BoundedJitCache``
build-outside-lock semantics, ``QueueSelector.stats`` queue_frac
reporting, the hoisted cohort sizing, and equal-instant async arrival
batching."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl as fl
from repro.core.aggregation import fused_aggregate, masked_mean_tree
from repro.core.strategies import LocalSpec
from repro.data.corpus import DataQueue
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import AsyncConfig, ProcessCompileCache
from repro.fl.selectors import QueueSelector
from repro.fl.server import BoundedJitCache
from repro.models import cnn


# --------------------------------------- masked_mean_tree accumulation fix

def _ref_mean_f64(stacked, sizes, mask):
    """The float64 numpy oracle for the masked weighted mean."""
    w = np.asarray(sizes, np.float64) * np.asarray(mask, np.float64)
    tot = max(w.sum(), 1e-12)

    def leaf(x):
        x = np.asarray(x, np.float64)
        wl = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x * wl).sum(axis=0) / tot

    return jax.tree.map(leaf, stacked)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_masked_mean_bf16_accumulates_in_f32(seed):
    """Summing a large cohort in bf16 (8 mantissa bits) loses mass; the
    fix accumulates in float32, so the result must sit within one bf16
    quantum of the float64 oracle for every seed."""
    rng = np.random.default_rng(seed)
    m = 64
    tree = {
        "w": jnp.asarray(rng.normal(size=(m, 37, 5)), jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=(m, 11)), jnp.bfloat16),
    }
    sizes = jnp.asarray(rng.integers(20, 200, size=m), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=m), jnp.float32)
    if float(jnp.sum(mask)) == 0:
        mask = mask.at[0].set(1.0)
    got = masked_mean_tree(tree, sizes, mask)
    want = _ref_mean_f64(tree, sizes, mask)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        # one bf16 ulp (2^-8 relative) around the true mean — the old
        # bf16-accumulated sum drifted by many ulps at m=64
        err = np.abs(np.asarray(g, np.float64) - w)
        tol = np.maximum(np.abs(w), 1e-3) * 2.0 ** -8
        assert np.all(err <= tol)


def test_masked_mean_f32_bitwise_unchanged():
    """Float32 leaves must run the identical ops as before the fix —
    fixed-seed golden histories depend on it."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(8, 13, 4)), jnp.float32)
    sizes = jnp.asarray(rng.integers(20, 200, size=8), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.float32)
    got = masked_mean_tree({"x": x}, sizes, mask)["x"]
    # the pre-fix formula, verbatim: weights cast to the leaf dtype
    w = sizes * mask
    tot = jnp.clip(jnp.sum(w), 1e-12, None)
    old = jnp.sum(x * w.reshape(-1, 1, 1).astype(x.dtype),
                  axis=0) / tot.astype(x.dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(old))


# ----------------------------------------------------- fused aggregation

def _cnn_like(rng, m):
    return {
        "conv1": {"w": jnp.asarray(rng.normal(size=(m, 3, 3, 1, 8)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)},
        "dense": {"w": jnp.asarray(rng.normal(size=(m, 128, 10)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(m, 10)), jnp.float32)},
    }


def _lm_like(rng, m):
    """Many small leaves + one embedding-shaped one, mixed dtypes."""
    tree = {"emb": jnp.asarray(rng.normal(size=(m, 96, 32)), jnp.float32)}
    for i in range(12):
        tree[f"blk{i}"] = {
            "attn": jnp.asarray(rng.normal(size=(m, 32, 32)), jnp.bfloat16),
            "ln": jnp.asarray(rng.normal(size=(m, 32)), jnp.float32),
        }
    return tree


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("treefn", [_cnn_like, _lm_like],
                         ids=["cnn", "lm"])
def test_fused_aggregate_matches_masked_mean(backend, treefn):
    """ISSUE acceptance: the one-launch flat segment-reduce matches the
    per-leaf tree_map mean to float32 tolerance on CNN and LM pytrees,
    on both the xla reference and the Pallas kernel."""
    rng = np.random.default_rng(42)
    m = 12
    tree = treefn(rng, m)
    sizes = jnp.asarray(rng.integers(20, 200, size=m), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=m), jnp.float32).at[0].set(1.)
    got = fused_aggregate(tree, sizes, mask, backend=backend)
    want = masked_mean_tree(tree, sizes, mask)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype
        assert g.shape == w.shape
        np.testing.assert_allclose(
            np.asarray(g, np.float64), np.asarray(w, np.float64),
            rtol=1e-5, atol=1e-5)


def test_fused_aggregator_registered():
    agg = fl.get("aggregator", "fused")
    assert agg.from_config(config=None, local=None).backend is None


# ------------------------------------------- BoundedJitCache lock scope

def test_cache_build_does_not_block_other_keys():
    """A slow make() on one key must not stall lookups of other keys —
    the old implementation held the lock across make()."""
    cache = BoundedJitCache(maxsize=4)
    slow_started = threading.Event()
    slow_release = threading.Event()

    def slow_make():
        slow_started.set()
        assert slow_release.wait(timeout=10)
        return "slow"

    t = threading.Thread(target=cache.get, args=("slow", slow_make))
    t.start()
    assert slow_started.wait(timeout=10)
    # while "slow" is building, an unrelated key must go straight through
    done = []
    t2 = threading.Thread(
        target=lambda: done.append(cache.get("fast", lambda: "fast")))
    t2.start()
    t2.join(timeout=5)
    assert done == ["fast"], "unrelated get blocked behind a slow build"
    slow_release.set()
    t.join(timeout=10)
    assert cache.get("slow", lambda: "rebuilt") == "slow"


def test_cache_same_key_builds_once():
    """Concurrent misses on ONE key dedupe onto a single build; waiters
    adopt the builder's entry (1 miss + N-1 hits in the stats)."""
    cache = ProcessCompileCache(maxsize=4)
    calls = []
    gate = threading.Event()

    def make():
        calls.append(1)
        gate.wait(timeout=10)
        return object()

    results = [None] * 4

    def worker(i):
        results[i] = cache.get("k", make)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.2)       # let every thread reach the miss path
    gate.set()
    for t in threads:
        t.join(timeout=10)
    assert len(calls) == 1
    assert all(r is results[0] for r in results)
    assert cache.stats()["misses"] == 1
    assert cache.stats()["hits"] == 3


def test_cache_failed_build_recovers():
    """An exception inside make() must release the per-key claim so the
    next caller becomes the builder instead of deadlocking."""
    cache = BoundedJitCache(maxsize=4)
    with pytest.raises(RuntimeError, match="boom"):
        cache.get("k", lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    assert cache.get("k", lambda: "ok") == "ok"
    assert len(cache) == 1


# ------------------------------------------------ QueueSelector.stats fix

class _FakeCorpusStats:
    """Duck-typed stats surface QueueSelector.bind_data consumes."""

    def __init__(self, n):
        self._ent = np.linspace(1.0, 2.0, n)
        self._sizes = np.full(n, 100, np.int64)

    def label_entropy(self):
        return self._ent

    def sizes(self):
        return self._sizes


def test_queue_frac_reports_last_applied_schedule():
    """stats()["queue_frac"] is the schedule the LAST select applied:
    None before any select, frac(0) after the first, frac(1) after the
    second — never a peek at the upcoming round (the old
    ``frac(round_idx - 1)`` reported round 0's frac at construction)."""
    q = DataQueue(start_frac=0.25, rounds_to_full=4)
    sel = QueueSelector(8, eps=1.0, seed=0, queue=q)
    sel.bind_data(_FakeCorpusStats(8))
    assert sel.stats()["queue_frac"] is None
    sel.select(4)
    assert sel.stats()["queue_frac"] == pytest.approx(q.frac(0))
    sel.select(4)
    assert sel.stats()["queue_frac"] == pytest.approx(q.frac(1))
    assert q.frac(1) != q.frac(0)      # the two sides really differ


def test_queue_frac_stays_none_unbound():
    """Unbound (no corpus stats) the queue is off: select() must not
    fabricate a schedule fraction."""
    sel = QueueSelector(8, eps=1.0, seed=0)
    sel.select(4)
    assert sel.stats()["queue_frac"] is None


# ------------------------------------------------------- cohort sizing

@pytest.mark.parametrize("n,c,want", [
    (25, 0.1, 2),     # banker's rounding: round(2.5) == 2, not 3
    (35, 0.1, 4),     # round(3.5) == 4 — half-to-even both directions
    (8, 0.5, 4),
    (8, 0.01, 1),     # floor of 1
    (32, 0.156, 5),   # the paper's Table 1 setting
])
def test_cohort_size_half_to_even(n, c, want):
    cfg = fl.ServerConfig(num_clients=n, participation=c)
    assert cfg.cohort_size() == want


# -------------------------------------- async equal-instant arrival batch

@pytest.fixture(scope="module")
def tiny():
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=60, test_per_class=15, hw=16,
        noise=0.4, seed=0)
    parts = partition("case1", ytr, 8, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    return data, params


def _async(tiny, **cfg):
    data, params = tiny
    return fl.build("fedentropy", cnn.apply, params, data,
                    fl.ServerConfig(num_clients=8, participation=0.5,
                                    seed=0),
                    LocalSpec(epochs=1, batch_size=20),
                    engine="async", runtime=AsyncConfig(**cfg))


def test_equal_instant_arrivals_screen_as_one_batch(tiny):
    """Regression: every event sharing the next arrival instant pops as
    ONE batch, tie-broken by dispatch sequence — within a cohort (the
    zero-latency reduction) and across cohorts (concurrency > cohort
    puts two cohorts' arrivals at the same instant)."""
    # within one cohort: default concurrency == cohort size
    server = _async(tiny)
    server._ensure_inflight()
    batch = server._pop_batch()
    assert len(batch) == 4                       # the whole cohort at t=0
    assert [e["seq"] for e in batch] == sorted(e["seq"] for e in batch)
    assert not server._events

    # across cohorts: two cohorts in flight, all eight events at t=0
    server2 = _async(tiny, concurrency=8)
    server2._ensure_inflight()
    batch2 = server2._pop_batch()
    assert len(batch2) == 8
    seqs = [e["seq"] for e in batch2]
    assert seqs == sorted(seqs) == list(range(8))
    assert len({e["t_arr"] for e in batch2}) == 1
    assert not server2._events
