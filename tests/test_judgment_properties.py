"""Property-based tests for Algorithm 1 (maximum entropy judgment).

Requires the ``hypothesis`` dev extra (``pip install -e .[dev]``); the
module skips cleanly when it is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.judgment import judge, judge_np


def _case(m, c, seed, concentration=0.3):
    r = np.random.default_rng(seed)
    p = r.dirichlet(np.full(c, concentration), size=m)
    sizes = r.integers(10, 500, m).astype(np.float64)
    return p, sizes


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(2, 20), st.integers(0, 100_000))
def test_property_jax_equals_oracle(m, c, seed):
    p, sizes = _case(m, c, seed, concentration=0.4)
    A, R, ent = judge_np(p, sizes)
    res = judge(jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32))
    mask_ref = np.zeros(m)
    mask_ref[A] = 1
    np.testing.assert_array_equal(np.asarray(res.mask), mask_ref)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(2, 20), st.integers(0, 100_000))
def test_property_final_entropy_not_below_initial(m, c, seed):
    p, sizes = _case(m, c, seed)
    res = judge(jnp.asarray(p, jnp.float32), jnp.asarray(sizes, jnp.float32))
    assert float(res.entropy) >= float(res.initial_entropy) - 1e-6
