"""Property-based optimizer tests.

Requires the ``hypothesis`` dev extra (``pip install -e .[dev]``); the
module skips cleanly when it is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import sgd


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-4, 0.5), st.floats(0.0, 0.95))
def test_property_sgd_step_size_scales(lr, momentum):
    opt = sgd(lr=lr, momentum=momentum)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.ones((3,))}
    p1, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - lr, rtol=1e-5)
