"""repro — FedEntropy (Ling et al., 2022) as a production JAX framework."""
__version__ = "1.0.0"
