"""FL training driver: FedEntropy over the mesh (or host devices).

Runs the gradient-level FedEntropy round (core/distributed.py) on real
data: the synthetic non-IID corpus is partitioned into logical clients
(case1/case2/dirichlet), the epsilon-greedy pools pick which clients feed
each mesh client-slot per round, and the judgment mask inside the step
decides whose gradients aggregate.

CPU-friendly: ``--mesh host`` uses whatever devices exist; reduced configs
via ``--reduced``. Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 20 --clients 8 --case case1 --mesh host
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..core.distributed import FedSpec, make_train_step
from ..data.synthetic import make_token_dataset
from ..fl.selectors import PoolSelector, UniformSelector
from ..optim import adamw, sgd
from ..checkpoint import save
from ..models.api import build_model
from ..sharding.ctx import use_mesh
from .mesh import make_host_mesh


def build_fl_corpus(cfg, num_clients: int, case: str, seq_len: int,
                    seed: int = 0):
    """Domain-skewed token corpus partitioned into logical FL clients."""
    num_domains = max(4, num_clients // 2)
    x, dom = make_token_dataset(
        vocab_size=min(cfg.vocab_size, 2048),
        num_domains=num_domains,
        docs_per_domain=max(64, 8 * num_clients),
        seq_len=seq_len, seed=seed)
    rng = np.random.default_rng(seed)
    clients: list[np.ndarray] = []
    if case == "case1":          # one domain per client
        for i in range(num_clients):
            idx = np.where(dom == i % num_domains)[0]
            clients.append(rng.permutation(idx))
    elif case == "case2":        # two domains per client
        for i in range(num_clients):
            a, b = i % num_domains, (i + 1) % num_domains
            idx = np.where((dom == a) | (dom == b))[0]
            clients.append(rng.permutation(idx))
    else:                         # dirichlet over domains
        props = rng.dirichlet(np.full(num_domains, 0.3), size=num_clients)
        for i in range(num_clients):
            ds = rng.choice(num_domains, size=256, p=props[i])
            idx = np.concatenate([
                rng.choice(np.where(dom == d0)[0], 1) for d0 in ds])
            clients.append(idx)
    return x, clients


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8,
                    help="mesh client slots per round (M)")
    ap.add_argument("--logical-clients", type=int, default=32,
                    help="logical FL population feeding the slots")
    ap.add_argument("--case", default="case1",
                    choices=["case1", "case2", "case3"])
    ap.add_argument("--per-client-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--no-fedentropy", action="store_true")
    ap.add_argument("--selector", default="pools",
                    choices=["pools", "uniform"],
                    help="repro.fl Selector driving client admission")
    ap.add_argument("--eps", type=float, default=0.8)
    ap.add_argument("--mesh", default="host")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    mesh = make_host_mesh()

    m = args.clients
    bsz = m * args.per_client_batch
    fed = FedSpec(num_clients=m, enabled=not args.no_fedentropy)
    opt = (sgd(lr=args.lr, momentum=0.5) if args.optimizer == "sgd"
           else adamw(lr=args.lr))
    step = make_train_step(model, opt, fed)

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    opt_state = opt.init(params)

    corpus, client_idx = build_fl_corpus(
        cfg, args.logical_clients, args.case, args.seq_len, args.seed)
    selector = (PoolSelector(args.logical_clients, args.eps, args.seed)
                if args.selector == "pools"
                else UniformSelector(args.logical_clients, args.seed + 1))
    rng = np.random.default_rng(args.seed)

    jitted = jax.jit(step, donate_argnums=(0, 1))
    t0 = time.time()
    with mesh, use_mesh(mesh):
        for it in range(args.steps):
            sel = selector.select(m)                    # logical clients
            rows = []
            for c in sel:
                take = rng.choice(client_idx[c], args.per_client_batch)
                rows.append(corpus[take, : args.seq_len + 1])
            tokens = jnp.asarray(np.concatenate(rows), jnp.int32)
            extra = {}
            if cfg.family == "vlm":
                extra["patches"] = jnp.zeros(
                    (bsz, cfg.num_patches, cfg.d_model), jnp.float32)
            if cfg.family == "encdec":
                extra["frames"] = jnp.zeros(
                    (bsz, cfg.encoder_seq, cfg.d_model), jnp.float32)
            params, opt_state, metrics = jitted(
                params, opt_state, {"tokens": tokens, **extra})
            mask = np.asarray(metrics["mask"])
            pos = [sel[i] for i in range(m) if mask[i] > 0]
            neg = [sel[i] for i in range(m) if mask[i] == 0]
            selector.update(pos, neg)
            print(f"step {it:4d} loss={float(metrics['loss']):.4f} "
                  f"pos={int(metrics['num_positive'])}/{m} "
                  f"ent={float(metrics['entropy']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
    dt = time.time() - t0
    print(f"done: {args.steps} rounds in {dt:.1f}s "
          f"({dt / args.steps:.2f}s/round); selector={selector.stats()}")
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, params,
                    meta={"arch": cfg.name, "selector": selector.stats()})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
