"""FL training driver, composed end-to-end from the ``repro.fl`` registry.

Two execution paths, one composition API:

* ``--engine mesh`` (default) — the gradient-level FedEntropy round
  (core/distributed.py): one jitted train step over the device mesh, the
  judge axis traced *inside* the step (``Judge.traced()``, optionally the
  Pallas sweep via ``--judge-backend pallas``), the selector feeding mesh
  client slots per round.
* ``--engine sequential | pipelined | async`` — the weights-level
  ``repro.fl`` server (paper Alg. 2 with E local epochs) over the same
  token corpus, built with ``fl.build(..., engine=...)``; ``pipelined``
  adds the runtime subsystem's mesh-sharded client fan-out and
  (``--speculate``) verdict speculation, ``async`` streams client updates
  under a simulated arrival clock (``--clock``) with per-arrival
  max-entropy admission, flushing every ``--buffer-size`` arrivals with
  ``--staleness-alpha`` damping.

Every axis — selector, judge, engine — resolves through ``repro.fl``
registries, so both paths run the identical composition code the
benchmarks and tests use. (At the gradient level, masked size-weighted
gradient averaging IS the weighted aggregator at E=1 — see
core/distributed.py's module docstring — which is why the mesh path has
no separate aggregator knob.)

CPU-friendly: ``--mesh host`` uses whatever devices exist; reduced configs
via ``--reduced``. Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 20 --clients 8 --case case1 --mesh host
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --engine pipelined --speculate --steps 10

LM quickstart (the scan engine at LM scale — eps-greedy pools folded on
device, O(cohort x vocab) stacked bytes per round via remat):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --engine scan --rounds-per-scan 4 --params-mode remat \
      --selector pools-traced --lm-objective window --steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from ..configs import ARCHS
from ..core.distributed import FedSpec, make_train_step
from ..data.synthetic import make_token_dataset
from ..optim import adamw, sgd
from ..checkpoint import save
from ..models.api import build_model
from ..sharding.ctx import use_mesh
from .mesh import make_host_mesh


def build_fl_corpus(cfg, num_clients: int, case: str, seq_len: int,
                    seed: int = 0):
    """Domain-skewed token corpus partitioned into logical FL clients."""
    num_domains = max(4, num_clients // 2)
    x, dom = make_token_dataset(
        vocab_size=min(cfg.vocab_size, 2048),
        num_domains=num_domains,
        docs_per_domain=max(64, 8 * num_clients),
        seq_len=seq_len, seed=seed)
    rng = np.random.default_rng(seed)
    clients: list[np.ndarray] = []
    if case == "case1":          # one domain per client
        for i in range(num_clients):
            idx = np.where(dom == i % num_domains)[0]
            clients.append(rng.permutation(idx))
    elif case == "case2":        # two domains per client
        for i in range(num_clients):
            a, b = i % num_domains, (i + 1) % num_domains
            idx = np.where((dom == a) | (dom == b))[0]
            clients.append(rng.permutation(idx))
    else:                         # dirichlet over domains
        props = rng.dirichlet(np.full(num_domains, 0.3), size=num_clients)
        for i in range(num_clients):
            ds = rng.choice(num_domains, size=256, p=props[i])
            idx = np.concatenate([
                rng.choice(np.where(dom == d0)[0], 1) for d0 in ds])
            clients.append(idx)
    return x, clients


def _components(args, *, host_oracle: bool):
    """Resolve the selector and judge axes from the ``repro.fl`` registry.

    ``host_oracle=True`` (server engines) keeps the host-side judge on the
    float64 numpy oracle — the verdict of record, and the check that
    catches float32 tie-margin misspeculation; ``--judge-backend`` only
    picks the *traced* implementation (mesh step / pipelined speculation).
    """
    sel_cls = fl.get("selector", args.selector)
    config = fl.ServerConfig(num_clients=args.logical_clients,
                             participation=args.clients /
                             max(args.logical_clients, 1),
                             eps=args.eps, seed=args.seed,
                             group_size=args.group_size,
                             num_clusters=args.num_clusters)
    selector = sel_cls.from_config(config=config, local=None)
    if args.judge == "maxent":
        judge = fl.MaxEntropyJudge(
            backend="numpy" if host_oracle else args.judge_backend)
    else:
        judge = fl.get("judge", args.judge)()
    return config, selector, judge


def lm_window_apply(model, cfg):
    """Adapter: (params, x:(B, L+1) tokens) -> ((B, L, V) next-token
    logits for targets ``x[:, 1:]``, feats) — the full-window LM contract
    :class:`repro.fl.LMWindowStrategy` (``--lm-objective window``)
    consumes. Every position trains, not just the final token; the soft
    label becomes the weighted mean next-token distribution over all
    positions (paper Eq. 2, LM analog)."""
    def apply_fn(params, x):
        batch = {"tokens": x[:, :-1]}
        b = x.shape[0]
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (b, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, _ = model.forward(params, batch)
        logits = logits.astype(jnp.float32)
        return logits, logits[:, -1, :]
    return apply_fn


def lm_client_apply(model, cfg):
    """Adapter: (params, x:(B, L) tokens) -> (next-token logits, feats) so
    the weights-level ``Server``/``client_update`` machinery drives an LM.
    Each sample is an (L,) window; the classification target is its final
    token, the soft label (paper Eq. 2) the mean next-token distribution —
    the LM analog of the per-device label signature."""
    def apply_fn(params, x):
        batch = {"tokens": x[:, :-1]}
        b = x.shape[0]
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (b, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, _ = model.forward(params, batch)
        last = logits[:, -1, :].astype(jnp.float32)
        return last, last
    return apply_fn


def stack_lm_clients(corpus, client_idx, samples: int, seq_len: int,
                     seed: int):
    """(N, S, L+1) token windows + final-token labels for the fl server."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for rows in client_idx:
        take = rng.choice(rows, samples)
        win = corpus[take, : seq_len + 1]
        xs.append(win)
        ys.append(win[:, -1])
    return {
        "x": jnp.asarray(np.stack(xs), jnp.int32),
        "y": jnp.asarray(np.stack(ys), jnp.int32),
        "w": jnp.ones((len(client_idx), samples), jnp.float32),
    }


def build_drift_events(args, config, corpus, client_idx) -> list:
    """One label-drift event at ``--drift-at``: half the clients (seeded
    choice) re-sample their windows from their ring-neighbor's domain
    rows with a fresh draw stream — the LM analog of a label-distribution
    re-partition (see ``repro.data.partition.drift_schedule``)."""
    n = config.num_clients
    rng = np.random.default_rng(args.seed)
    k = max(1, n // 2)
    drifting = sorted(int(c) for c in
                      rng.choice(n, size=k, replace=False))
    rotated = [client_idx[(c + 1) % n] for c in drifting]
    new = stack_lm_clients(corpus, rotated, args.samples_per_client,
                           args.seq_len, args.seed + 1)
    return [fl.DriftEvent(
        round=args.drift_at, clients=tuple(drifting),
        data={key: np.asarray(v) for key, v in new.items()})]


def run_server_engine(args, cfg, model, corpus, client_idx) -> None:
    """Weights-level rounds through ``fl.build`` (sequential or pipelined)."""
    config, selector, judge = _components(args, host_oracle=True)
    data = stack_lm_clients(corpus, client_idx, args.samples_per_client,
                            args.seq_len, args.seed)
    drift = (build_drift_events(args, config, corpus, client_idx)
             if args.drift_at >= 0 else None)
    if args.engine == "async":
        if args.speculate:
            raise SystemExit(
                "--speculate is a pipelined-engine knob: the async engine "
                "has no round barrier to overlap the oracle with")
        runtime = fl.AsyncConfig(
            buffer_size=args.buffer_size,
            staleness_alpha=args.staleness_alpha,
            clock=args.clock, seed=args.seed)
    elif args.engine == "scan":
        if args.speculate:
            raise SystemExit(
                "--speculate is a pipelined-engine knob: the scan engine "
                "speculates every in-scan verdict already (the float64 "
                "oracle replays each R-round block)")
        runtime = fl.ScanConfig(rounds_per_scan=args.rounds_per_scan,
                                spec_backend=args.judge_backend,
                                params_mode=args.params_mode)
    else:
        runtime = fl.RuntimeConfig(speculate=args.speculate,
                                   spec_backend=args.judge_backend)
    if args.method:
        # named composition (e.g. fedcat): its own selector/judge axes
        # resolve from the registry via config (--group-size sizes chains);
        # refuse explicit axis flags rather than silently dropping them
        if args.selector != "pools" or args.judge != "maxent":
            raise SystemExit(
                f"--method {args.method} names a full composition; drop "
                "--selector/--judge (compose axes via the legacy flags "
                "without --method instead)")
        composition, selector, judge = args.method, None, None
    else:
        composition = "fedavg" if args.no_fedentropy else "fedentropy"
        if args.no_fedentropy:
            judge = None
    window = args.lm_objective == "window"
    if window and args.method:
        raise SystemExit(
            f"--lm-objective window swaps the client strategy for lmstep; "
            f"--method {args.method} composes its own strategy axis — "
            "drop one of the two")
    if args.num_clusters > 1 and window:
        raise SystemExit(
            "--num-clusters > 1 runs the plain vmapped ClientUpdate "
            "(per-client bank centers); --lm-objective window swaps in "
            "the lmstep strategy's own client fn — drop one of the two")
    apply_fn = (lm_window_apply if window else lm_client_apply)(model, cfg)
    server = fl.build(
        composition, apply_fn, model.init(
            jax.random.PRNGKey(args.seed)), data, config,
        fl.LocalSpec(epochs=args.local_epochs, lr=args.lr,
                     batch_size=args.per_client_batch),
        selector=selector, strategy="lmstep" if window else None,
        judge=judge,
        # the cluster axis: --num-clusters>1 opts any composition into the
        # K-center bank with the --cluster-assign assigner; K=1 leaves a
        # named clustered composition (e.g. --method ifca) on its own
        # recipe, which then reduces to the single-model path exactly
        cluster=args.cluster_assign if args.num_clusters > 1 else None,
        drift=drift,
        engine=args.engine, runtime=runtime, data_plane=args.data_plane)
    if args.dryrun:
        rep = server.corpus.memory_report()
        m = max(1, int(round(config.num_clients * config.participation)))
        print(f"dryrun: engine={args.engine} data_plane={rep['plane']}")
        print(f"  host-mapped bytes:     {rep['host_mapped_bytes']}"
              f" (mmap={rep['host_is_mmap']})")
        print(f"  device-resident bytes: {rep['device_resident_bytes']}")
        print(f"  staging bytes:         {rep['staging_nbytes']}")
        print(f"  clients: N={rep['num_clients']} cohort |S_t|={m} "
              f"(~{server.corpus.cohort_nbytes(m)}B/round host-slice "
              "equivalent)")
        return
    t0 = time.time()
    for it in range(args.steps):
        rec = server.round()
        extra = ""
        if "spec_hit" in rec:
            extra = (f" spec={'hit' if rec['spec_hit'] else 'miss'}"
                     f"{' redispatched' if rec['redispatched'] else ''}")
        if "staleness" in rec:
            extra = (f" t={rec['flush_time']:.2f}"
                     f" stale_max={max(rec['staleness'])}"
                     f" buf={rec['buffer_occupancy']}")
        if "cluster" in rec:
            occ = np.bincount(np.asarray(rec["cluster"]),
                              minlength=args.num_clusters)
            extra += f" clusters={'/'.join(str(int(c)) for c in occ)}"
        if "drift" in rec:
            extra += f" drift={sum(len(c) for c in rec['drift'])}cl"
        print(f"round {it:4d} pos={len(rec['positive'])}/"
              f"{len(rec['selected'])} ent={rec['entropy']:.4f}"
              f" comm={rec['comm']['total_bytes']}B{extra}", flush=True)
    dt = time.time() - t0
    # read stats off the SERVER's selector: a speculative hit adopts a
    # deepcopy, orphaning the local reference built above
    stats = server.selector.stats()
    print(f"done: {args.steps} rounds in {dt:.1f}s "
          f"({dt / args.steps:.2f}s/round); selector={stats}")
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, server.global_params,
                    meta={"arch": cfg.name, "engine": args.engine,
                          "selector": stats})
        print("checkpoint:", path)


def run_mesh_engine(args, cfg, model, corpus, client_idx) -> None:
    """Gradient-level rounds: one jitted mesh step, judge traced inside."""
    _, selector, judge = _components(args, host_oracle=False)
    mesh = make_host_mesh()
    m = args.clients
    bsz = m * args.per_client_batch
    fed = FedSpec(num_clients=m, enabled=not args.no_fedentropy)
    opt = (sgd(lr=args.lr, momentum=0.5) if args.optimizer == "sgd"
           else adamw(lr=args.lr))
    step = make_train_step(model, opt, fed, judge_fn=judge.traced())

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    opt_state = opt.init(params)
    rng = np.random.default_rng(args.seed)

    jitted = jax.jit(step, donate_argnums=(0, 1))
    t0 = time.time()
    with mesh, use_mesh(mesh):
        for it in range(args.steps):
            sel = selector.select(m)                    # logical clients
            rows = []
            for c in sel:
                take = rng.choice(client_idx[c], args.per_client_batch)
                rows.append(corpus[take, : args.seq_len + 1])
            tokens = jnp.asarray(np.concatenate(rows), jnp.int32)
            extra = {}
            if cfg.family == "vlm":
                extra["patches"] = jnp.zeros(
                    (bsz, cfg.num_patches, cfg.d_model), jnp.float32)
            if cfg.family == "encdec":
                extra["frames"] = jnp.zeros(
                    (bsz, cfg.encoder_seq, cfg.d_model), jnp.float32)
            params, opt_state, metrics = jitted(
                params, opt_state, {"tokens": tokens, **extra})
            mask = np.asarray(metrics["mask"])
            pos = [sel[i] for i in range(m) if mask[i] > 0]
            neg = [sel[i] for i in range(m) if mask[i] == 0]
            selector.update(pos, neg)
            print(f"step {it:4d} loss={float(metrics['loss']):.4f} "
                  f"pos={int(metrics['num_positive'])}/{m} "
                  f"ent={float(metrics['entropy']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
    dt = time.time() - t0
    print(f"done: {args.steps} rounds in {dt:.1f}s "
          f"({dt / args.steps:.2f}s/round); selector={selector.stats()}")
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, params,
                    meta={"arch": cfg.name, "selector": selector.stats()})
        print("checkpoint:", path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8,
                    help="client slots per round (M = |S_t|)")
    ap.add_argument("--logical-clients", type=int, default=32,
                    help="logical FL population feeding the slots")
    ap.add_argument("--case", default="case1",
                    choices=["case1", "case2", "case3"])
    ap.add_argument("--per-client-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--no-fedentropy", action="store_true")
    ap.add_argument("--method", default="",
                    choices=["", "fedentropy", "fedavg", "fedcat",
                             "fedcat+maxent", "fedentropy+queue", "ifca",
                             "ifca+maxent", "fesem"],
                    help="named repro.fl composition (server engines); "
                         "fedcat chains grouped devices sequentially, "
                         "fedcat+maxent filters chains with judgment, "
                         "fedentropy+queue ranks clients by corpus "
                         "entropy with a dynamic data queue; ifca/"
                         "ifca+maxent/fesem run the K-center clustered "
                         "ModelBank (size via --num-clusters)")
    ap.add_argument("--num-clusters", type=int, default=1,
                    help="K ModelBank centers (server engines); 1 keeps "
                         "the single global model, >1 clusters clients "
                         "via --cluster-assign with per-cluster judgment "
                         "and aggregation")
    ap.add_argument("--cluster-assign", default="ifca",
                    choices=["ifca", "fesem"],
                    help="cluster assigner when --num-clusters > 1: ifca "
                         "= per-round loss argmin over the centers, "
                         "fesem = sticky weight-distance re-filing")
    ap.add_argument("--drift-at", type=int, default=-1,
                    help="re-partition half the clients' local data at "
                         "this round (label drift; server engines); -1 "
                         "disables")
    ap.add_argument("--group-size", type=int, default=2,
                    help="FedCAT chain length (fedcat compositions)")
    ap.add_argument("--engine", default="mesh",
                    choices=["mesh", "sequential", "pipelined", "async",
                             "scan"],
                    help="mesh = gradient-level jitted step; sequential/"
                         "pipelined/async/scan = weights-level repro.fl "
                         "engines (async streams arrivals through "
                         "max-entropy admission; scan folds R rounds "
                         "into one lax.scan program)")
    ap.add_argument("--rounds-per-scan", type=int, default=4,
                    help="scan engine: rounds folded per jitted scan "
                         "block (needs --selector uniform or "
                         "pools-traced to fold >1)")
    ap.add_argument("--params-mode", default="stack",
                    choices=["stack", "remat"],
                    help="scan engine rewind points: stack keeps R "
                         "post-round param copies in the scan's ys, "
                         "remat re-runs confirmed rounds on a mismatch "
                         "— O(cohort*vocab) stacked bytes per round, "
                         "the LM-scale mode")
    ap.add_argument("--lm-objective", default="last-token",
                    choices=["last-token", "window"],
                    help="server engines: last-token treats each window "
                         "as a classification sample (final token is "
                         "the label); window trains every next-token "
                         "position via the lmstep strategy (the LM "
                         "fine-tune objective)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="async engine: screened arrivals per flush "
                         "(0 = cohort size, the reduction case)")
    ap.add_argument("--staleness-alpha", type=float, default=0.0,
                    help="async engine: (1+tau)^-alpha damping of "
                         "admitted updates (0 = off)")
    ap.add_argument("--clock", default="zero",
                    choices=["zero", "uniform", "straggler"],
                    help="async engine: simulated per-client arrival "
                         "latency model (seeded, virtual time)")
    ap.add_argument("--selector", default="pools",
                    choices=["pools", "pools-traced", "uniform", "queue"],
                    help="repro.fl Selector driving client admission "
                         "(pools-traced = the paper's eps-greedy pools "
                         "on a jax.random stream, scan-foldable; queue "
                         "= entropy-ranked dynamic data queues, stats "
                         "bound from the server's ClientCorpus)")
    ap.add_argument("--judge", default="maxent", choices=["maxent", "none"],
                    help="repro.fl Judge axis (both engines)")
    ap.add_argument("--judge-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="traced judge implementation (mesh step / "
                         "pipelined speculation)")
    ap.add_argument("--speculate", action="store_true",
                    help="pipelined engine: overlap oracle judgment with "
                         "the next round's client compute")
    ap.add_argument("--data-plane", default="auto",
                    choices=["resident", "streaming", "auto"],
                    help="server engines: where client data lives — "
                         "resident stacks all N clients on device, "
                         "streaming keeps them host-side and uploads "
                         "only the cohort (prefetched under --speculate),"
                         " auto picks resident while N fits")
    ap.add_argument("--dryrun", action="store_true",
                    help="server engines: build the server, print the "
                         "data-plane memory report, and exit without "
                         "training")
    ap.add_argument("--local-epochs", type=int, default=1,
                    help="E local epochs (server engines)")
    ap.add_argument("--samples-per-client", type=int, default=16,
                    help="local dataset size per client (server engines)")
    ap.add_argument("--eps", type=float, default=0.8)
    ap.add_argument("--mesh", default="host")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)

    corpus, client_idx = build_fl_corpus(
        cfg, args.logical_clients, args.case, args.seq_len, args.seed)
    if args.engine == "mesh":
        if args.data_plane != "auto" or args.dryrun:
            # the mesh engine feeds token batches straight into the jitted
            # step — there is no corpus object to place on a plane or to
            # report memory for
            raise SystemExit(
                "--data-plane/--dryrun need a weights-level engine: use "
                "--engine sequential, pipelined, or async (the server "
                "owns the data-plane corpus)")
        if args.selector == "queue":
            # the mesh engine has no ClientCorpus to bind entropy stats or
            # data-queue schedules to — it would silently run uniform
            raise SystemExit(
                "--selector queue needs a weights-level engine: use "
                "--engine sequential or pipelined (the server binds the "
                "corpus stats the queue selector ranks on)")
        if args.method:
            # the gradient-level step has no composition axis to honor a
            # named recipe (fedcat chains thread whole models); refusing
            # beats silently running the default fedentropy path
            raise SystemExit(
                f"--method {args.method} needs a weights-level engine: "
                "use --engine sequential or pipelined (the mesh engine "
                "is composed via --no-fedentropy/--selector/--judge)")
        if args.num_clusters > 1 or args.drift_at >= 0:
            # the mesh step threads ONE replicated model through the jitted
            # program and owns no corpus object to re-partition mid-run
            raise SystemExit(
                "--num-clusters/--drift-at need a weights-level engine: "
                "use --engine sequential or pipelined (the server carries "
                "the ModelBank and applies the drift schedule)")
        run_mesh_engine(args, cfg, model, corpus, client_idx)
    else:
        run_server_engine(args, cfg, model, corpus, client_idx)


if __name__ == "__main__":
    main()
