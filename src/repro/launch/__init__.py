from . import hlo_analysis, mesh
