import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) lowers,
compiles, and fits — without TPU hardware.

For each combination this driver builds the production mesh (16x16 single
pod / 2x16x16 multi-pod over 512 forced host devices), constructs the
FedEntropy train step (train shapes) or the serving prefill/decode step
(inference shapes) with full param/optimizer/cache shardings, then runs
``jax.jit(...).lower(**specs).compile()`` and records:

  * compiled.memory_analysis()  — per-device bytes (does it fit 16 GB?)
  * compiled.cost_analysis()    — XLA's aggregate (loop bodies counted 1x)
  * loop-aware HLO walk         — FLOPs / HBM bytes / collective bytes with
                                  while trip counts applied (hlo_analysis)
  * MODEL_FLOPS = 6·N_active·D  — analytic useful compute for the ratio

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --multi-pod --out results.json
"""
import argparse
import json
import time
import traceback
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding

from ..configs import ARCHS, ASSIGNED, SHAPES
from ..configs.base import ModelConfig, ShapeConfig
from ..core.distributed import (
    FedSpec, cache_logical_axes, make_serve_steps, make_train_step,
    param_logical_axes,
)
from ..models.api import (
    build_model, decode_window, input_specs, supported,
)
from ..optim import sgd
from ..sharding.ctx import use_mesh
from ..sharding.specs import logical_to_pspec, tree_shardings
from .hlo_analysis import analyze_hlo_text, cost_analysis_dict
from .mesh import fl_clients_for, make_production_mesh

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _merged_rules(rules):
    from ..sharding.specs import DEFAULT_RULES
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    return merged


def batch_logical(cfg: ModelConfig, specs: dict) -> dict:
    """Logical axes for each batch input."""
    out = {}
    for k, v in specs.items():
        if k == "tokens":
            out[k] = ("batch", None)
        elif k in ("patches", "frames"):
            out[k] = ("batch", None, None)
        elif k == "cache":
            out[k] = cache_logical_axes(v)
        else:
            out[k] = (None,) * len(v.shape)
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                params_shape) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference) analytic FLOPs,
    N = non-embedding active params (+ the LM-head matmul counted via the
    head/tied-embedding table)."""
    total_active = 0
    head_flops_per_tok = 2 * cfg.d_model * cfg.padded_vocab
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        if names[-2:] == ("tok", "embed") or names[-2:] == ("tok", "head"):
            continue
        n = int(np.prod(leaf.shape))
        if "moe" in names and names[-1] in ("w_in", "w_gate", "w_out"):
            n = n // cfg.num_experts * cfg.experts_per_token
        total_active += n
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * total_active * tokens + mult / 2 * head_flops_per_tok * \
        tokens


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              mesh=None, save_hlo: str | None = None,
              attn: str = "xla", chunked_head: bool = False,
              remat: str | None = None,
              capacity_factor: float | None = None,
              seq_rule: bool = False,
              kv_time_rule: bool = False) -> dict[str, Any]:
    """attn/chunked_head/remat/capacity_factor/seq_rule are the §Perf
    hillclimbing knobs; defaults reproduce the baseline."""
    cfg = ARCHS[arch]
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if capacity_factor is not None:
        cfg = cfg.replace(moe_capacity_factor=capacity_factor)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod,
                           "variant": {"attn": attn,
                                       "chunked_head": chunked_head,
                                       "remat": cfg.remat,
                                       "cf": cfg.moe_capacity_factor,
                                       "seq_rule": seq_rule,
                                       "kv_time_rule": kv_time_rule}}
    ok, why = supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    from ..kernels import ops as kops
    kops.set_default_backend("xla" if attn == "xla" else attn)

    t0 = time.time()
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    window = decode_window(cfg, shape)
    rules = {}
    if seq_rule:   # sequence-parallel attention activations ("model" axis)
        rules["seq"] = ("model",)
    if kv_time_rule:   # shard the KV-cache time dim over "model" (decode
        rules["kv_time"] = ("model",)   # with kv_heads % model != 0)
    rules = rules or None

    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_axes = param_logical_axes(params_shape)
    p_sh = tree_shardings(p_axes, params_shape, mesh)
    specs = input_specs(cfg, shape)
    b_axes = batch_logical(cfg, specs)
    b_sh = jax.tree.map(
        lambda ax, s: NamedSharding(
            mesh, logical_to_pspec(ax, s.shape, mesh, _merged_rules(rules))),
        b_axes, specs, is_leaf=lambda x: isinstance(x, tuple))

    with mesh, use_mesh(mesh, rules):
        if shape.kind == "train":
            fed = FedSpec(num_clients=fl_clients_for(mesh),
                          chunked_head=chunked_head)
            opt = sgd(lr=0.01, momentum=0.5)
            step = make_train_step(model, opt, fed)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            o_axes = {"mu": p_axes, "count": ()}
            o_sh = tree_shardings(o_axes, opt_shape, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, opt_shape, specs)
        elif shape.kind == "prefill":
            prefill_step, _ = make_serve_steps(model, window=window)
            jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            _, decode_step = make_serve_steps(model, window=window)
            cache_spec = specs["cache"]
            cache_sh = b_sh["cache"]
            tok_sh = b_sh["tokens"]
            jitted = jax.jit(decode_step,
                             in_shardings=(p_sh, cache_sh, tok_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_spec,
                                   specs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled.cost_analysis())
    hlo_text = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo_text)
    hlo = analyze_hlo_text(hlo_text)

    mf = model_flops(cfg, shape, params_shape)
    per_dev_flops = hlo["flops"]
    compute_s = per_dev_flops / PEAK_FLOPS
    memory_s = hlo["hbm_bytes"] / HBM_BW
    coll_s = hlo["collective_bytes_total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    rec.update({
        "status": "ok",
        "mesh": dict(mesh.shape),
        "num_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": _mem_dict(mem),
        "cost_analysis_flops": float(ca.get("flops", 0.0)),
        "cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        "hlo_flops_per_device": per_dev_flops,
        "hlo_hbm_bytes_per_device": hlo["hbm_bytes"],
        "collective_bytes": hlo["collective_bytes"],
        "collective_counts": hlo["collective_counts"],
        "collective_bytes_total": hlo["collective_bytes_total"],
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(per_dev_flops * n_dev, 1.0),
        "roofline": dict(terms, dominant=dominant),
    })
    return rec


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return f"{r['arch']:24s} {r['shape']:12s} SKIP  ({r['reason'][:60]})"
    t = r["roofline"]
    mem = r["memory_analysis"]
    per_dev_gb = (mem.get("argument_size_in_bytes", 0) +
                  mem.get("temp_size_in_bytes", 0)) / 2**30
    return (f"{r['arch']:24s} {r['shape']:12s} "
            f"cmp={t['compute_s']*1e3:9.2f}ms "
            f"mem={t['memory_s']*1e3:9.2f}ms "
            f"col={t['collective_s']*1e3:9.2f}ms "
            f"dom={t['dominant'][:-2]:10s} "
            f"useful={r['useful_flops_ratio']*100:5.1f}% "
            f"dev={per_dev_gb:6.2f}GiB "
            f"compile={r['compile_s']:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--out", default="", help="write JSON records here")
    ap.add_argument("--save-hlo", default="",
                    help="directory to dump compiled HLO text per combo")
    ap.add_argument("--attn", default="xla",
                    choices=["xla", "blockwise"],
                    help="attention impl (blockwise = flash-style scan)")
    ap.add_argument("--chunked-head", action="store_true",
                    help="stream vocab head in seq chunks")
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "full", "dots"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--seq-rule", action="store_true",
                    help="shard attention activations' seq dim over model")
    ap.add_argument("--kv-time-rule", action="store_true",
                    help="shard KV-cache time dim over model (decode)")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    records = []
    for arch in archs:
        for shape in shapes:
            hlo_path = (os.path.join(
                args.save_hlo, f"{arch}_{shape}"
                f"{'_mp' if args.multi_pod else ''}.hlo")
                if args.save_hlo else None)
            try:
                r = run_combo(arch, shape, multi_pod=args.multi_pod,
                              save_hlo=hlo_path, attn=args.attn,
                              chunked_head=args.chunked_head,
                              remat=args.remat,
                              capacity_factor=args.capacity_factor,
                              seq_rule=args.seq_rule,
                              kv_time_rule=args.kv_time_rule)
            except Exception as e:  # a failure here is a bug in the system
                r = {"arch": arch, "shape": shape, "status": "error",
                     "multi_pod": args.multi_pod,
                     "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-2000:]}
            records.append(r)
            if r["status"] == "error":
                print(f"{arch:24s} {shape:12s} ERROR {r['error'][:90]}",
                      flush=True)
            else:
                print(fmt_row(r), flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"== {n_ok} ok / {n_skip} skipped / {n_err} errors ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
