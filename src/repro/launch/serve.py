"""Batched serving driver: prefill a batch of prompts, then decode.

Serves the trained global model (FedEntropy's output is a plain model —
serving exercises the same prefill/decode steps the dry-run lowers).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..models.api import build_model
from ..checkpoint import restore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt_dir:
        params, meta, step = restore(args.ckpt_dir, params)
        print(f"restored step {step}: {meta}")

    b, s = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    extra = cfg.num_patches if cfg.family == "vlm" else 0
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, window=args.window or None,
                                    cache_len=s + extra + args.gen)
    )(params, batch)
    print(f"prefill {b}x{s}: {time.time() - t0:.2f}s")

    step_fn = jax.jit(lambda p, c, t: model.decode_step(
        p, c, t, window=args.window or None))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    key = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step_fn(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({1000 * dt / max(args.gen - 1, 1):.1f} ms/step)")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()
