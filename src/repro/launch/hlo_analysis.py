"""Loop-aware cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each while body ONCE, so scan-over-layers
models under-report FLOPs/bytes by ~L x. This walker parses
``compiled.as_text()``, builds the computation call graph, extracts
``known_trip_count`` from while ops, and accumulates per-computation

  * flops              — dot/conv ops (2 * prod(result) * contracting);
  * hbm_bytes          — bytes actually accessed: fusion call sites count
                         result + per-operand access (a fusion parameter
                         consumed only by dynamic-slice counts the sliced
                         bytes, not the whole buffer — critical for
                         scan-over-layers, where stacked (L, ...) params
                         are sliced once per iteration);
  * collective_bytes   — per collective kind, operand-size sum (the spec'd
                         convention for the roofline collective term)

scaled by while trip counts up to ENTRY. dynamic-(update-)slice / gather /
scatter count their accessed region (2x read+write), matching
HloCostAnalysis' in-place semantics rather than whole-buffer operand sizes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "while", "call", "conditional", "after-all", "partition-id",
             "replica-id", "fusion"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    calls_full: list = field(default_factory=list)    # (callee, mult)
    calls_flops: list = field(default_factory=list)   # fusion interiors
    param_order: list = field(default_factory=list)   # names in order
    # param -> accessed bytes if ONLY consumed by dynamic-slice, else None
    param_sliced: dict = field(default_factory=dict)


_COMP_NAME_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+\"?(\d+)')
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|\S+)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")


def _split_top(s: str) -> list[str]:
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    parts.append(s[start:])
    return [p.strip() for p in parts if p.strip()]


def _parse_header(line: str):
    if not line.endswith("{") or "->" not in line or "(" not in line:
        return None
    nm = _COMP_NAME_RE.match(line)
    if nm is None:
        return None
    head = line[: line.rindex("->")]
    lp, rp = head.find("("), head.rfind(")")
    if rp <= lp:
        return None
    symtab, order = {}, []
    for part in _split_top(head[lp + 1: rp]):
        if ":" in part:
            pname, ptype = part.split(":", 1)
            symtab[pname.strip()] = ptype.strip()
            order.append(pname.strip())
    return nm.group(1), line.lstrip().startswith("ENTRY"), symtab, order


def parse_hlo(text: str) -> dict[str, CompStats]:
    comps: dict[str, CompStats] = {}
    entry = None
    cur: CompStats | None = None
    symtab: dict[str, str] = {}
    params: set[str] = set()

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _parse_header(line)
        if hdr is not None:
            name, is_entry, symtab, order = hdr
            cur = CompStats(param_order=list(order),
                            param_sliced={p: 0 for p in order})
            params = set(order)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = (m.group("name"), m.group("type").strip(),
                                 m.group("op"), m.group("args"))
        symtab[name] = rtype
        operands = re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0])

        # track param usage for the fusion-slice analysis
        rbytes = _shape_bytes(rtype)
        for i, o in enumerate(operands):
            if o in params and cur.param_sliced.get(o) is not None:
                if op == "dynamic-slice" and i == 0:
                    cur.param_sliced[o] += rbytes
                elif op == "parameter":
                    pass
                else:
                    cur.param_sliced[o] = None      # general use -> full

        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", line)
            trip = _TRIP_RE.search(line)
            n = int(trip.group(1)) if trip else 1
            if body:
                cur.calls_full.append((body.group(1), n))
            continue
        if op == "call":
            callee = re.search(r"to_apply=%?([\w.\-]+)", line)
            if callee:
                cur.calls_full.append((callee.group(1), 1))
            continue
        if op == "fusion":
            callee_m = re.search(r"calls=%?([\w.\-]+)", line)
            cur.calls_flops.append(
                (callee_m.group(1) if callee_m else "", 1,
                 name, list(operands), rtype))
            continue
        if op == "conditional":
            for grp in re.findall(r"(?:true|false|branch)_computations?="
                                  r"[{%]?([\w.\-,%\s]+)", line):
                for cc in re.findall(r"([\w.\-]+)", grp):
                    cur.calls_full.append((cc, 1))
            continue

        obytes = sum(_shape_bytes(symtab.get(o, "")) for o in operands)

        if op in COLLECTIVES or any(op.startswith(c + "-")
                                    for c in COLLECTIVES):
            kind = next(c for c in COLLECTIVES if op.startswith(c))
            cur.coll_bytes[kind] = cur.coll_bytes.get(kind, 0) + obytes
            cur.coll_count[kind] = cur.coll_count.get(kind, 0) + 1
            cur.hbm_bytes += obytes + rbytes
            continue

        if op == "dot":
            dims, _ = _shape_dims(rtype)
            lhs_t = symtab.get(operands[0], "") if operands else ""
            ldims, _ = _shape_dims(lhs_t)
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if cm and cm.group(1):
                for d in cm.group(1).split(","):
                    if int(d) < len(ldims):
                        k *= ldims[int(d)]
            out_n = 1
            for d in dims:
                out_n *= d
            cur.flops += 2.0 * out_n * k
        elif op == "convolution":
            dims, _ = _shape_dims(rtype)
            rhs_t = symtab.get(operands[1], "") if len(operands) > 1 else ""
            rdims, _ = _shape_dims(rhs_t)
            out_n = 1
            for d in dims:
                out_n *= d
            k = 1
            for d in rdims[:-1]:
                k *= d
            cur.flops += 2.0 * out_n * k

        if op in ("dynamic-slice", "gather"):
            cur.hbm_bytes += 2 * rbytes
            continue
        if op in ("dynamic-update-slice", "scatter"):
            upd = (_shape_bytes(symtab.get(operands[1], ""))
                   if len(operands) > 1 else rbytes)
            cur.hbm_bytes += 2 * upd
            continue

        if op not in _SKIP_OPS:
            cur.hbm_bytes += rbytes + obytes

    comps["__entry_name__"] = entry  # type: ignore[assignment]
    comps["__symtabs__"] = None      # type: ignore[assignment]
    # stash a global symbol resolver: we re-parse operand types lazily via
    # the per-computation loop above (operand types were resolved inline).
    return comps


def aggregate(comps: dict) -> dict:
    entry = comps.get("__entry_name__")
    memo: dict[str, tuple] = {}

    def visit(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if not isinstance(c, CompStats) or depth > 64:
            return (0.0, 0.0, {}, {})
        fl, hb = c.flops, c.hbm_bytes
        cb = dict(c.coll_bytes)
        cc = dict(c.coll_count)
        for callee, mult in c.calls_full:
            f2, h2, cb2, cc2 = visit(callee, depth + 1)
            fl += mult * f2
            hb += mult * h2
            for k, v in cb2.items():
                cb[k] = cb.get(k, 0) + mult * v
            for k, v in cc2.items():
                cc[k] = cc.get(k, 0) + mult * v
        for callee, mult, iname, _ops, _rt in c.calls_flops:
            f2, h2, cb2, cc2 = visit(callee, depth + 1)
            fl += mult * f2          # interior dots count
            for k, v in cb2.items():
                cb[k] = cb.get(k, 0) + mult * v
            for k, v in cc2.items():
                cc[k] = cc.get(k, 0) + mult * v
        memo[name] = (fl, hb, cb, cc)
        return memo[name]

    # second pass for fusion call-site bytes: needs operand types, which
    # live in the caller's scope — handled during parse via a callback-free
    # approximation: fusion site bytes were NOT added in parse; add them
    # here by re-walking is impossible without operand types, so parse
    # stores them alongside. (See _fusion_site_bytes below.)
    fl, hb, cb, cc = visit(entry) if entry else (0.0, 0.0, {}, {})
    return {
        "flops": fl,
        "hbm_bytes": hb,
        "collective_bytes": cb,
        "collective_bytes_total": float(sum(cb.values())),
        "collective_counts": cc,
    }


def analyze_hlo_text(text: str) -> dict:
    comps = parse_hlo(text)
    _add_fusion_site_bytes(text, comps)
    return aggregate(comps)


def cost_analysis_dict(ca) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return one properties dict; jax 0.4.3x returns a
    per-device LIST of such dicts (and None when analysis is unavailable).
    Returns a single flat dict — for the list shape, the first device's
    properties (all devices run the same SPMD program)."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


def _add_fusion_site_bytes(text: str, comps: dict) -> None:
    """Second pass: for every fusion call site, add result bytes + operand
    access bytes (sliced-only params count their slice sizes)."""
    cur_name = None
    symtab: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _parse_header(line)
        if hdr is not None:
            cur_name, _, symtab, _ = hdr
            symtab = dict(symtab)
            continue
        if line.strip() == "}":
            cur_name = None
            continue
        if cur_name is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op = (m.group("name"), m.group("type").strip(),
                           m.group("op"))
        symtab[name] = rtype
        if op != "fusion":
            continue
        cur = comps.get(cur_name)
        if not isinstance(cur, CompStats):
            continue
        callee_m = re.search(r"calls=%?([\w.\-]+)", line)
        callee = comps.get(callee_m.group(1)) if callee_m else None
        operands = re.findall(r"%([\w.\-]+)",
                              m.group("args").split("),", 1)[0])
        total = _shape_bytes(rtype)
        for i, o in enumerate(operands):
            full = _shape_bytes(symtab.get(o, ""))
            if (isinstance(callee, CompStats) and
                    i < len(callee.param_order)):
                pname = callee.param_order[i]
                sliced = callee.param_sliced.get(pname)
                if sliced is not None and sliced > 0:
                    total += min(sliced, full)
                    continue
            total += full
        cur.hbm_bytes += total
