"""Production mesh builders (functions only — importing this module never
touches jax device state).

Single pod : (16, 16)        axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

The dry-run forces 512 host devices via XLA_FLAGS *before* any jax import
(see dryrun.py); real deployments get the same shapes from the TPU slice.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Small mesh over whatever devices exist — used by tests/examples."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def fl_clients_for(mesh: Mesh) -> int:
    """One FL client group per ("pod","data") mesh row."""
    m = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return max(m, 1)
