"""Uniform model API: ``build_model(cfg)`` -> Model(init/forward/loss/
prefill/decode_step/init_cache/input_specs).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given input-shape config — weak-type-correct, shardable,
zero allocation — used by the multi-pod dry-run and by ``jax.eval_shape``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer

# dense archs use this ring-buffer window for the long_500k decode shape
# (the explicitly-implemented sub-quadratic sliding-window variant).
LONG_CONTEXT_WINDOW = 8192


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., tuple[jax.Array, jax.Array]]
    hidden: Callable[..., tuple[jax.Array, jax.Array]]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    init_cache: Callable[..., Any]

    def loss(self, params, batch, *, window: int | None = None):
        logits, aux = self.forward(params, batch, window=window)
        tokens = batch["tokens"]
        loss = transformer.lm_loss(self.cfg, logits, tokens,
                                   batch.get("loss_weights"))
        return loss + self.cfg.router_aux_weight * aux, logits


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        mod = encdec
    elif cfg.family == "cnn":
        raise ValueError("use repro.models.cnn directly for the paper CNN")
    else:
        mod = transformer
    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        forward=lambda params, batch, **kw: mod.forward(
            cfg, params, batch, **kw),
        hidden=lambda params, batch, **kw: mod.hidden(
            cfg, params, batch, **kw),
        prefill=lambda params, batch, **kw: mod.prefill(
            cfg, params, batch, **kw),
        decode_step=lambda params, cache, tokens, **kw: mod.decode_step(
            cfg, params, cache, tokens, **kw),
        init_cache=lambda batch, cache_len, dtype=None: mod.init_cache(
            cfg, batch, cache_len, dtype),
    )


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sliding window used for a decode shape (0 = full attention)."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def attn_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV-cache length for decode: ring buffer when windowed."""
    w = decode_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


def supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) in scope? (the one documented skip)."""
    if cfg.family == "encdec" and shape.name == "long_500k":
        return False, ("whisper context is bounded by construction "
                       "(1500 frames / 448-token decoder); 500k-token "
                       "decode has no analogue — documented skip")
    if cfg.family == "cnn":
        return False, "paper CNN is exercised by the FL simulator, not LM shapes"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every input of (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    act = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        text = s
        specs: dict[str, Any] = {}
        if cfg.family == "vlm":
            text = s - cfg.num_patches
            specs["patches"] = sds((b, cfg.num_patches, cfg.d_model), act)
        if cfg.family == "encdec":
            specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), act)
        specs["tokens"] = sds((b, text), tok)
        return specs

    # decode: one new token + a full cache of seq_len context
    cache_len = attn_cache_len(cfg, shape)
    model = build_model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(b, cache_len))
    return {"tokens": sds((b, 1), tok), "cache": cache}
