"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060], TPU-adapted.

Projection layout follows the Mamba2 reference: one fused in_proj produces
(z, x, B, C, dt); a short causal conv runs over (x, B, C); the SSD recurrence
y_t = C_t . h_t,  h_t = exp(dt_t A) h_{t-1} + dt_t x_t (x) B_t is evaluated
either chunk-parallel (kernels.ops.ssd -> Pallas on TPU) or sequentially
(decode: O(1) state update carried in the cache).

Cache per layer: {"conv": (B, ssm_conv-1, conv_ch), "state": (B, H, P, N)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding.ctx import shard_act
from .layers import dense_apply, dense_init, pdtype_of, rms_norm


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_inner                       # expand * d_model
    heads = d_in // cfg.ssm_headdim
    n = cfg.ssm_state
    g = cfg.ssm_ngroups
    conv_ch = d_in + 2 * g * n                 # conv over (x, B, C)
    proj = 2 * d_in + 2 * g * n + heads        # z, x, B, C, dt
    return d_in, heads, n, g, conv_ch, proj


def ssm_init(key, cfg: ModelConfig) -> dict:
    d_in, heads, n, g, conv_ch, proj = _dims(cfg)
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (heads,)) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    # inverse softplus so softplus(dt_bias) == dt at init
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], cfg, cfg.d_model, proj),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) *
                   (cfg.ssm_conv ** -0.5)).astype(pdtype_of(cfg)),
        "conv_b": jnp.zeros((conv_ch,), pdtype_of(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), pdtype_of(cfg)),
        "out_proj": dense_init(ks[3], cfg, d_in, cfg.d_model),
    }


def _split_proj(cfg, zxbcdt):
    d_in, heads, n, g, _, _ = _dims(cfg)
    z, xc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * g * n], axis=-1)
    return z, xc, dt                           # xc = conv channels (x,B,C)


def _split_conv(cfg, xc):
    d_in, heads, n, g, _, _ = _dims(cfg)
    x, b_mat, c_mat = jnp.split(xc, [d_in, d_in + g * n], axis=-1)
    return x, b_mat, c_mat


def _causal_conv(w, bias, x):
    """Depthwise causal conv over (B, L, C) with taps (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None].astype(x.dtype)
              for i in range(k))
    return jax.nn.silu(out + bias.astype(x.dtype))


def ssm_block(cfg: ModelConfig, p: dict, u: jax.Array) -> jax.Array:
    """Full-sequence SSD (train / prefill). u: (B, L, d_model)."""
    d_in, heads, n, g, _, _ = _dims(cfg)
    bsz, l, _ = u.shape
    zxbcdt = dense_apply(p["in_proj"], u)
    z, xc, dt = _split_proj(cfg, zxbcdt)
    xc = _causal_conv(p["conv_w"], p["conv_b"], xc)
    x, b_mat, c_mat = _split_conv(cfg, xc)

    x = shard_act(x.reshape(bsz, l, heads, cfg.ssm_headdim),
                  ("batch", "seq", "ssm_inner", None))
    b_mat = b_mat.reshape(bsz, l, g, n)
    c_mat = c_mat.reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    y, _ = ops.ssd(x, dt, a, b_mat, c_mat, chunk=cfg.ssm_chunk)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, l, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = dense_apply(p["out_proj"], y)
    return shard_act(out, ("batch", "seq", "embed"))


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, heads, n, g, conv_ch, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, heads, cfg.ssm_headdim, n), jnp.float32),
    }


def ssm_decode_step(cfg: ModelConfig, p: dict, u: jax.Array,
                    cache: dict) -> tuple[jax.Array, dict]:
    """One-token recurrent step. u: (B, 1, d_model)."""
    d_in, heads, n, g, conv_ch, _ = _dims(cfg)
    bsz = u.shape[0]
    zxbcdt = dense_apply(p["in_proj"], u)
    z, xc, dt = _split_proj(cfg, zxbcdt)

    # conv with carried window: (B, K-1, C) ++ current -> take last output
    hist = jnp.concatenate([cache["conv"], xc], axis=1)     # (B, K, C)
    w = p["conv_w"].astype(xc.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(
        xc.dtype)
    xc1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]

    x, b_mat, c_mat = _split_conv(cfg, xc1)
    x = x.reshape(bsz, heads, cfg.ssm_headdim)
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, n), heads // g, axis=1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, n), heads // g, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    decay = jnp.exp(dt1 * a[None, :])                        # (B, H)
    upd = (dt1[..., None] * x.astype(jnp.float32))[..., None] * \
        b_mat.astype(jnp.float32)[:, :, None, :]             # (B,H,P,N)
    state = decay[..., None, None] * cache["state"] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state,
                   c_mat.astype(jnp.float32)).astype(u.dtype)
    y = y + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = dense_apply(p["out_proj"], y)
    return out, {"conv": new_conv, "state": state}
