"""The paper's CNN (Appendix Table 5) — LeNet-style, pure functional JAX.

conv5x5(6) -> maxpool2 -> conv5x5(16) -> maxpool2 -> FC(120) -> FC(84)
-> FC(num_classes).  ``apply`` returns (logits, features) where features is
the penultimate (84-d) representation — used by Moon's contrastive term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,))}


def _dense_init(key, din, dout):
    w = jax.random.normal(key, (din, dout)) * jnp.sqrt(2.0 / din)
    return {"w": w, "b": jnp.zeros((dout,))}


def init(key: jax.Array, image_hw: int = 32, channels: int = 3,
         num_classes: int = 10) -> dict:
    k = jax.random.split(key, 5)
    h = (image_hw - 4) // 2        # after conv1 + pool
    h = (h - 4) // 2               # after conv2 + pool
    flat = h * h * 16
    return {
        "conv1": _conv_init(k[0], 5, 5, channels, 6),
        "conv2": _conv_init(k[1], 5, 5, 6, 16),
        "fc1": _dense_init(k[2], flat, 120),
        "fc2": _dense_init(k[3], 120, 84),
        "fc3": _dense_init(k[4], 84, num_classes),
    }


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, H, W, C) float -> (logits (B, classes), features (B, 84))."""
    h = _pool(jax.nn.relu(_conv(params["conv1"], x)))
    h = _pool(jax.nn.relu(_conv(params["conv2"], h)))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    feats = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    logits = feats @ params["fc3"]["w"] + params["fc3"]["b"]
    return logits, feats
