"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs`` feeds precomputed frame embeddings (B, encoder_seq, d_model).
Sinusoidal positions on both sides (the real model uses learned decoder
positions capped at 448; the assigned decode shapes reach 32k, so we use
the unbounded sinusoidal form — recorded in DESIGN.md).

Decoder block: self-attn (causal) -> cross-attn (to cached encoder KV) ->
MLP. Encoder: bidirectional self-attn blocks over the frames.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.ctx import shard_act
from . import attention as attn
from .layers import (
    dtype_of, embed_apply, embed_init, logits_apply, mlp_apply, mlp_init,
    norm_apply, norm_init,
)


def sinusoidal(positions: jax.Array, dim: int) -> jax.Array:
    """(…,) int positions -> (…, dim) float32 sinusoidal embeddings."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg, cfg.d_model),
            "attn": attn.attn_init(k1, cfg),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(k2, cfg, cfg.d_model, cfg.d_ff)}


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg, cfg.d_model),
            "attn": attn.attn_init(k1, cfg),
            "lnx": norm_init(cfg, cfg.d_model),
            "xattn": attn.attn_init(k2, cfg, cross=True),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(k3, cfg, cfg.d_model, cfg.d_ff)}


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    return {
        "tok": embed_init(ke, cfg),
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg))(
            jax.random.split(kenc, cfg.num_encoder_layers)),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg))(
            jax.random.split(kdec, cfg.num_layers)),
        "final_norm": norm_init(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T, d_model) precomputed frontend embeddings."""
    b, t, _ = frames.shape
    x = frames.astype(dtype_of(cfg))
    x = x + sinusoidal(jnp.arange(t), cfg.d_model).astype(x.dtype)[None]
    x = shard_act(x, ("batch", "frames", "embed"))

    def body(h, lp):
        a, _ = attn.self_attention(cfg, lp["attn"],
                                   norm_apply(cfg, lp["ln1"], h),
                                   causal=False)
        h = h + a
        h = h + mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm_apply(cfg, params["enc_norm"], x)


def _dec_embed(cfg, params, tokens, offset=0):
    x = embed_apply(cfg, params["tok"], tokens)
    pos = jnp.arange(tokens.shape[1]) + offset
    return x + sinusoidal(pos, cfg.d_model).astype(x.dtype)[None]


def hidden(cfg: ModelConfig, params: dict, batch: dict,
           *, window: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Final-norm decoder hidden states (pre-logits), + aux=0."""
    window = cfg.sliding_window if window is None else window
    enc = encode(cfg, params, batch["frames"])
    x = _dec_embed(cfg, params, batch["tokens"])

    def body(h, lp):
        a, _ = attn.self_attention(cfg, lp["attn"],
                                   norm_apply(cfg, lp["ln1"], h),
                                   causal=True, window=window)
        h = h + a
        kv = attn.cross_kv(cfg, lp["xattn"], enc)
        h = h + attn.cross_attention(cfg, lp["xattn"],
                                     norm_apply(cfg, lp["lnx"], h), kv)
        h = h + mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
        return h, None

    body = (jax.checkpoint(body) if cfg.remat == "full" else body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    h = norm_apply(cfg, params["final_norm"], x)
    return h, jnp.zeros((), jnp.float32)


def forward(cfg: ModelConfig, params: dict, batch: dict,
            *, window: int | None = None) -> tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B,S), "frames": (B,T,D)} -> (logits, aux=0)."""
    h, aux = hidden(cfg, params, batch, window=window)
    return logits_apply(cfg, params["tok"], h), aux


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> dict:
    dtype = dtype or dtype_of(cfg)
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers
    return {
        "index": jnp.zeros((), jnp.int32),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
        "layers": jax.vmap(
            lambda _: attn.cache_init(cfg, batch, cache_len, dtype)
        )(jnp.arange(L)),
        "cross": {"k": jnp.zeros((L, batch, cfg.encoder_seq, kh, hd), dtype),
                  "v": jnp.zeros((L, batch, cfg.encoder_seq, kh, hd), dtype)},
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            *, window: int | None = None,
            cache_len: int | None = None) -> tuple[jax.Array, dict]:
    window = cfg.sliding_window if window is None else window
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = max(cache_len or s, s)
    x = _dec_embed(cfg, params, tokens)
    cache = init_cache(cfg, b, cache_len)

    def body(h, lp):
        a, kv = attn.self_attention(cfg, lp["attn"],
                                    norm_apply(cfg, lp["ln1"], h),
                                    causal=True, window=window)
        h = h + a
        ckv = attn.cross_kv(cfg, lp["xattn"], enc)
        h = h + attn.cross_attention(cfg, lp["xattn"],
                                     norm_apply(cfg, lp["lnx"], h), ckv)
        h = h + mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
        return h, (kv, ckv)

    x, (kvs, ckvs) = jax.lax.scan(body, x, params["dec_layers"])
    from .transformer import _place, _pos_tags
    cache["layers"] = jax.tree.map(lambda t: _place(t, cache_len), kvs)
    cache["cross"] = ckvs
    cache["pos"] = _pos_tags(s, cache_len)
    cache["index"] = jnp.asarray(s, jnp.int32)
    h = norm_apply(cfg, params["final_norm"], x)
    return logits_apply(cfg, params["tok"], h), cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, *, window: int | None = None
                ) -> tuple[jax.Array, dict]:
    window = cfg.sliding_window if window is None else window
    index = cache["index"]
    pos_tags = cache["pos"]
    x = _dec_embed(cfg, params, tokens, offset=index)

    def body(h, scanned):
        lp, lc, xc = scanned
        a, upd = attn.decode_self_attention(
            cfg, lp["attn"], norm_apply(cfg, lp["ln1"], h), lc, index,
            pos_tags, window=window)
        h = h + a
        h = h + attn.cross_attention(cfg, lp["xattn"],
                                     norm_apply(cfg, lp["lnx"], h), xc)
        h = h + mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
        return h, upd

    x, upd = jax.lax.scan(body, x, (params["dec_layers"], cache["layers"],
                                    cache["cross"]))
    new_cache = dict(cache)
    new_cache["layers"] = {"k": upd["k"], "v": upd["v"]}
    new_cache["pos"] = upd["pos"][0]
    new_cache["index"] = index + 1
    h = norm_apply(cfg, params["final_norm"], x)
    return logits_apply(cfg, params["tok"], h), new_cache
