"""Top-k MoE with sort-based static-shape dispatch, expert-parallel aware.

Dispatch (TPU-native, no dynamic shapes):
  1. router top-k over experts -> (T, k) indices + renormalized probs;
  2. flatten assignments, stable-argsort by expert id;
  3. position-in-expert = rank - first-rank-of-expert (via searchsorted);
  4. scatter tokens into an (E, C, D) capacity buffer (overflow dropped —
     standard capacity-factor semantics), expert einsum, gather back,
     combine with gate probs.

Sharding: experts -> "model" axis; the capacity axis -> batch axes. Under
pjit the dispatch scatter/gather lowers to all-to-all-like collectives;
the §Perf pass replaces this with an explicit shard_map lax.all_to_all.

Aux load-balance loss (Switch-style): E * sum_e f_e * p_e, where f_e is the
fraction of tokens routed to e and p_e the mean router prob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..sharding.ctx import shard_act
from .layers import dense_init, pdtype_of


def moe_init(key, cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": dense_init(ks[0], cfg, d, e, scale=0.02),
        "w_in": (jax.random.normal(ks[1], (e, d, f)) * std_in).astype(
            pdtype_of(cfg)),
        "w_gate": (jax.random.normal(ks[2], (e, d, f)) * std_in).astype(
            pdtype_of(cfg)),
        "w_out": (jax.random.normal(ks[3], (e, f, d)) * std_out).astype(
            pdtype_of(cfg)),
    }
    return p


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    k, e = cfg.experts_per_token, cfg.num_experts
    c = int(num_tokens * k / e * cfg.moe_capacity_factor)
    # MXU-friendly multiple of 8, at least 4
    return max(4, (c + 7) // 8 * 8)


def _route(cfg: ModelConfig, router_w, xt: jax.Array):
    """Shared routing math. xt: (T, D) -> (top_p, top_i, aux)."""
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = (xt @ router_w.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    top_p, top_i = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.clip(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return top_p, top_i, aux


def _dispatch_indices(cfg: ModelConfig, top_i: jax.Array):
    """Sort-based dispatch bookkeeping. top_i: (T, k)."""
    t, k = top_i.shape
    flat_e = top_i.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first
    token_of = order // k
    return order, sorted_e, pos, token_of


def moe_block_shard_map(cfg: ModelConfig, p: dict, x: jax.Array,
                        mesh) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map + lax.all_to_all (GShard-style).

    Experts live on the "model" axis; expert weights are additionally
    FSDP-sharded on the batch axes and all-gathered per layer. Dispatch:
    local sort-based pack into an (E, C_loc, D) buffer -> all_to_all over
    "model" (split experts / concat capacity) -> local expert einsum ->
    all_to_all back -> local combine. All collectives are explicit, so the
    roofline collective term reads straight off the HLO.

    This is the production path; the pjit path below is the naive variant
    kept for comparison (XLA replicates its scatter — see EXPERIMENTS §Perf).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    bsz, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch = int(np.prod([mesh.shape[a] for a in batch_axes]))
    ep = mesh.shape["model"]
    e_loc = e // ep
    # tokens must also split over the "model" axis or every model rank
    # routes identical copies and each expert does ep-x redundant work.
    b_loc = bsz // n_batch
    if s % ep == 0:
        xspec_dims = (batch_axes if batch_axes else None, "model", None)
        t_loc = b_loc * (s // ep)
        tok_axes = batch_axes + ("model",)
    elif b_loc % ep == 0:
        xspec_dims = (batch_axes + ("model",), None, None)
        t_loc = (b_loc // ep) * s
        tok_axes = batch_axes + ("model",)
    else:  # replicate over model (tiny decode batches only)
        xspec_dims = (batch_axes if batch_axes else None, None, None)
        t_loc = b_loc * s
        tok_axes = batch_axes
    cap = _capacity(cfg, t_loc)

    def local(xb, router_w, w_in, w_gate, w_out):
        # xb: (B_loc, S, D); w_*: (E_loc, D_loc, F) FSDP-sharded on D
        if batch_axes:
            w_in_f = jax.lax.all_gather(w_in, batch_axes, axis=1,
                                        tiled=True)
            w_gate_f = jax.lax.all_gather(w_gate, batch_axes, axis=1,
                                          tiled=True)
            w_out_f = jax.lax.all_gather(w_out, batch_axes, axis=2,
                                         tiled=True)
        else:
            w_in_f, w_gate_f, w_out_f = w_in, w_gate, w_out
        xt = xb.reshape(-1, d)                               # (T_loc, D)
        top_p, top_i, aux = _route(cfg, router_w, xt)
        if tok_axes:
            aux = jax.lax.pmean(aux, tok_axes)
        order, sorted_e, pos, token_of = _dispatch_indices(cfg, top_i)

        buf = jnp.zeros((e, cap, d), x.dtype)
        buf = buf.at[sorted_e, pos].set(xt[token_of], mode="drop")
        # exchange: split experts over "model", gather capacity shards
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)                 # (E_loc, C*ep, D)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in_f.astype(x.dtype))
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate_f.astype(x.dtype))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                       w_out_f.astype(x.dtype))
        y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                               tiled=True)                   # (E, C, D)
        gathered = y[sorted_e, pos]
        kept = (pos < cap)[:, None].astype(x.dtype)
        gate = top_p.reshape(-1)[order][:, None].astype(x.dtype)
        out = jnp.zeros((t_loc, d), x.dtype).at[token_of].add(
            gathered * gate * kept)
        return out.reshape(xb.shape), aux

    bspec = P(*xspec_dims)
    wspec_in = P("model", batch_axes if batch_axes else None, None)
    wspec_out = P("model", None, batch_axes if batch_axes else None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec_in, wspec_in, wspec_out),
        out_specs=(bspec, P()),
        check_rep=False)
    out, aux = fn(x, p["router"]["w"], p["w_in"], p["w_gate"], p["w_out"])
    return out, aux


def moe_block(cfg: ModelConfig, p: dict, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss ()).

    Uses the shard_map expert-parallel path when a mesh context with a
    "model" axis is active and the batch divides the batch axes; otherwise
    the single-device pjit path.
    """
    from ..sharding import ctx as shard_ctx
    c = shard_ctx.current()
    if c is not None and "model" in c.mesh.shape and \
            cfg.num_experts % c.mesh.shape["model"] == 0:
        batch_axes = tuple(a for a in ("pod", "data") if a in c.mesh.shape)
        n_batch = int(np.prod([c.mesh.shape[a] for a in batch_axes]))
        if x.shape[0] % max(n_batch, 1) == 0:
            return moe_block_shard_map(cfg, p, x, c.mesh)
    return moe_block_pjit(cfg, p, x)


def moe_block_pjit(cfg: ModelConfig, p: dict, x: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Naive data-parallel-friendly MoE (reference path)."""
    bsz, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = bsz * s
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)

    top_p, top_i, aux = _route(cfg, p["router"]["w"], xt)
    order, sorted_e, pos, token_of = _dispatch_indices(cfg, top_i)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, pos].set(xt[token_of], mode="drop")
    buf = shard_act(buf, ("experts", "capacity", None))

    # ---- expert computation ------------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype))
    y = shard_act(y, ("experts", "capacity", None))

    # ---- combine -----------------------------------------------------------
    gathered = y[sorted_e, pos]                               # (T*k, D)
    kept = (pos < cap)[:, None].astype(x.dtype)
    gate = top_p.reshape(-1)[order][:, None].astype(x.dtype)
    contrib = gathered * gate * kept
    out = jnp.zeros((t, d), x.dtype).at[token_of].add(contrib)
    out = out.reshape(bsz, s, d)
    return shard_act(out, ("batch", "seq", "embed")), aux
