from . import api, attention, cnn, encdec, layers, moe, ssm, transformer
from .api import Model, build_model, input_specs
