"""Grouped-query attention with qk-norm, RoPE variants, sliding windows and
a position-tagged KV cache (full-length or ring-buffer).

Cache layout per layer: {"k": (B, L, K, hd), "v": (B, L, K, hd)}.
The model-level cache additionally carries {"index": (), "pos": (L,)} where
``pos[slot]`` is the global position stored in that slot (-1 = empty). A
ring buffer (L == window < seq_len) makes long_500k decode O(window) for
dense architectures — the sub-quadratic variant required by the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding.ctx import shard_act
from .layers import apply_rope, dense_apply, dense_init, pdtype_of, rms_norm


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], cfg, d, h * hd, bias=cfg.attn_bias),
        "w_k": dense_init(ks[1], cfg, d, kh * hd, bias=cfg.attn_bias),
        "w_v": dense_init(ks[2], cfg, d, kh * hd, bias=cfg.attn_bias),
        "w_o": dense_init(ks[3], cfg, h * hd, d),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), pdtype_of(cfg))
        p["k_norm"] = jnp.ones((hd,), pdtype_of(cfg))
    return p


def _project_q(cfg, p, x):
    b, s, _ = x.shape
    q = dense_apply(p["w_q"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def _project_kv(cfg, p, x):
    b, s, _ = x.shape
    k = dense_apply(p["w_k"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense_apply(p["w_v"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def self_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                      # (B, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    positions: jax.Array | None = None,   # (B, S) global positions
) -> tuple[jax.Array, dict]:
    """Full-sequence self attention (train / prefill). Returns (out, kv)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))
    out = ops.attention(q, k, v, causal=causal, window=window)
    out = shard_act(out, ("batch", "seq", "heads", None))
    out = dense_apply(p["w_o"], out.reshape(b, s, -1))
    return shard_act(out, ("batch", "seq", "embed")), {"k": k, "v": v}


def cache_init(cfg: ModelConfig, batch: int, cache_len: int,
               dtype) -> dict:
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, cache_len, kh, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kh, hd), dtype)}


def decode_self_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,             # (B, 1, D)
    kv_cache: dict,           # this layer's {"k","v"} (B, L, K, hd)
    index: jax.Array,         # ()  global decode position
    pos_tags: jax.Array,      # (L,) global position per slot (-1 empty)
    *,
    window: int = 0,
) -> tuple[jax.Array, dict]:
    """One decode step; writes slot index % L (ring when L < seq_len)."""
    b = x.shape[0]
    L = kv_cache["k"].shape[1]
    positions = jnp.broadcast_to(index[None, None], (b, 1))
    q = _project_q(cfg, p, x)
    k_new, v_new = _project_kv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
    k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.rope_style)

    slot = jnp.mod(index, L)
    k = jax.lax.dynamic_update_slice(
        kv_cache["k"], k_new.astype(kv_cache["k"].dtype),
        (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(
        kv_cache["v"], v_new.astype(kv_cache["v"].dtype),
        (0, slot, 0, 0))
    tags = pos_tags.at[slot].set(index)
    out = ops.attention(
        q, k, v, causal=True, window=window, q_offset=positions[:, :1],
        kv_positions=jnp.broadcast_to(tags[None], (b, L)))
    out = dense_apply(p["w_o"], out.reshape(b, 1, -1))
    return out, {"k": k, "v": v, "pos": tags}


def cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                 # (B, S, D) decoder states
    enc_kv: dict,                 # {"k","v"}: (B, T, K, hd) cached encoder KV
) -> jax.Array:
    b, s, _ = x.shape
    q = _project_q(cfg, p, x)     # no rope on cross attention (whisper)
    out = ops.attention(q, enc_kv["k"], enc_kv["v"], causal=False)
    return dense_apply(p["w_o"], out.reshape(b, s, -1))


def cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array) -> dict:
    k, v = _project_kv(cfg, p, enc_out)
    return {"k": k, "v": v}
