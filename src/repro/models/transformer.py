"""Decoder-only LM assembled from blocks, scan-over-layers.

Families:
  dense  — [norm->attn, norm->mlp] x L
  moe    — [norm->attn, norm->moe] x L
  ssm    — [norm->mamba2] x L
  hybrid — groups of (attn_every-1) ssm blocks + 1 SHARED attention block
           (zamba2): outer scan over groups, inner scan over the ssm stack;
           the shared block's weights live once, its KV cache per group.

Layer params are stacked on a leading axis and consumed by ``lax.scan`` so
HLO size / compile time are depth-independent (94-layer models compile on
the CPU host). ``cfg.remat`` wraps the block body in ``jax.checkpoint``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    dtype_of, embed_apply, embed_init, logits_apply, mlp_apply, mlp_init,
    norm_apply, norm_init,
)

# --------------------------------------------------------------- block defs


def _block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe",
            "ssm": "ssm"}[cfg.family] if cfg.family != "hybrid" else "hybrid"


def _attn_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg, cfg.d_model),
         "attn": attn.attn_init(k1, cfg),
         "ln2": norm_init(cfg, cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg, cfg.d_model, cfg.d_ff)
    return p


def _ssm_block_init(key, cfg):
    return {"ln1": norm_init(cfg, cfg.d_model),
            "ssm": ssm_mod.ssm_init(key, cfg)}


def _stacked(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl, ka = jax.random.split(key, 3)
    params: dict[str, Any] = {"tok": embed_init(ke, cfg),
                              "final_norm": norm_init(cfg, cfg.d_model)}
    kind = _block_kind(cfg)
    if kind in ("dense", "moe"):
        params["layers"] = _stacked(
            lambda k: _attn_block_init(k, cfg), kl, cfg.num_layers)
    elif kind == "ssm":
        params["layers"] = _stacked(
            lambda k: _ssm_block_init(k, cfg), kl, cfg.num_layers)
    else:  # hybrid
        groups, per = _hybrid_shape(cfg)
        params["ssm_layers"] = jax.vmap(
            lambda k: _stacked(lambda kk: _ssm_block_init(kk, cfg), k, per)
        )(jax.random.split(kl, groups))
        params["shared_attn"] = _attn_block_init(ka, cfg)
    if cfg.family == "vlm":
        kp = jax.random.fold_in(key, 7)
        params["patch_proj"] = {
            "w": (jax.random.normal(kp, (cfg.d_model, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(jnp.dtype(cfg.param_dtype))}
    return params


def _hybrid_shape(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.attn_every - 1                       # ssm blocks per group
    groups = cfg.num_layers // cfg.attn_every
    return groups, per


# --------------------------------------------------------------- full pass


def _attn_block(cfg, p, x, *, window):
    h, _ = attn.self_attention(cfg, p["attn"], norm_apply(cfg, p["ln1"], x),
                               causal=True, window=window)
    x = x + h
    if "moe" in p:
        h, aux = moe_mod.moe_block(cfg, p["moe"], norm_apply(cfg, p["ln2"], x))
    else:
        h, aux = mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["ln2"], x)), 0.0
    return x + h, aux


def _ssm_block(cfg, p, x):
    return x + ssm_mod.ssm_block(cfg, p["ssm"], norm_apply(cfg, p["ln1"], x))


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def backbone(cfg: ModelConfig, params: dict, x: jax.Array,
             *, window: int | None = None) -> tuple[jax.Array, jax.Array]:
    """(B, S, D) -> (hidden (B, S, D), aux_loss ()). Full-sequence pass."""
    window = cfg.sliding_window if window is None else window
    kind = _block_kind(cfg)

    if kind in ("dense", "moe"):
        def body(carry, lp):
            h, aux = carry
            h, a = _attn_block(cfg, lp, h, window=window)
            return (h, aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, body),
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    elif kind == "ssm":
        def body(carry, lp):
            return _ssm_block(cfg, lp, carry), None
        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    else:  # hybrid: groups of ssm + one shared attention block
        shared = params["shared_attn"]

        def group(carry, gp):
            h = carry

            def inner(c, lp):
                return _ssm_block(cfg, lp, c), None
            h, _ = jax.lax.scan(inner, h, gp)
            h, _ = _attn_block(cfg, shared, h, window=window)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(cfg, group), x,
                            params["ssm_layers"])
        aux = jnp.zeros((), jnp.float32)
    return norm_apply(cfg, params["final_norm"], x), aux


def embed_tokens(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    x = embed_apply(cfg, params["tok"], batch["tokens"])
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)          # (B, P, D)
        patches = patches @ params["patch_proj"]["w"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    return x


def hidden(cfg: ModelConfig, params: dict, batch: dict,
           *, window: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Final-norm hidden states over text positions (pre-logits), + aux."""
    x = embed_tokens(cfg, params, batch)
    h, aux = backbone(cfg, params, x, window=window)
    if cfg.family == "vlm":                      # logits only on text slots
        h = h[:, cfg.num_patches:]
    return h, aux


def forward(cfg: ModelConfig, params: dict, batch: dict,
            *, window: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Training/eval forward. Returns (logits over text positions, aux)."""
    h, aux = hidden(cfg, params, batch, window=window)
    return logits_apply(cfg, params["tok"], h), aux


def lm_loss(cfg: ModelConfig, logits: jax.Array, tokens: jax.Array,
            weights: jax.Array | None = None) -> jax.Array:
    """Next-token CE, fp32. logits: (B,S,V); tokens: (B,S)."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tg[..., None], axis=-1)[..., 0]
    if weights is not None:
        w = weights[:, 1:]
        return jnp.sum(nll * w) / jnp.clip(jnp.sum(w), 1e-9)
    return jnp.mean(nll)


# --------------------------------------------------------------- serving

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> dict:
    dtype = dtype or dtype_of(cfg)
    kind = _block_kind(cfg)
    cache: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if kind in ("dense", "moe"):
        cache["pos"] = jnp.full((cache_len,), -1, jnp.int32)
        cache["layers"] = jax.vmap(
            lambda _: attn.cache_init(cfg, batch, cache_len, dtype)
        )(jnp.arange(cfg.num_layers))
    elif kind == "ssm":
        cache["layers"] = jax.vmap(
            lambda _: ssm_mod.ssm_cache_init(cfg, batch, dtype)
        )(jnp.arange(cfg.num_layers))
    else:
        groups, per = _hybrid_shape(cfg)
        cache["pos"] = jnp.full((cache_len,), -1, jnp.int32)
        cache["ssm"] = jax.vmap(jax.vmap(
            lambda _: ssm_mod.ssm_cache_init(cfg, batch, dtype)))(
                jnp.arange(groups * per).reshape(groups, per))
        cache["attn"] = jax.vmap(
            lambda _: attn.cache_init(cfg, batch, cache_len, dtype)
        )(jnp.arange(groups))
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, *, window: int | None = None
                ) -> tuple[jax.Array, dict]:
    """One-token decode. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
    window = cfg.sliding_window if window is None else window
    kind = _block_kind(cfg)
    index = cache["index"]
    x = embed_apply(cfg, params["tok"], tokens)
    new_cache = dict(cache)

    if kind in ("dense", "moe"):
        pos_tags = cache["pos"]

        def body(carry, scanned):
            h = carry
            lp, lc = scanned
            hn = norm_apply(cfg, lp["ln1"], h)
            a, updated = attn.decode_self_attention(
                cfg, lp["attn"], hn, lc, index, pos_tags, window=window)
            h = h + a
            if "moe" in lp:
                m, _ = moe_mod.moe_block(cfg, lp["moe"],
                                         norm_apply(cfg, lp["ln2"], h))
            else:
                m = mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
            h = h + m
            return h, {"k": updated["k"], "v": updated["v"],
                       "pos": updated["pos"]}

        x, upd = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = {"k": upd["k"], "v": upd["v"]}
        new_cache["pos"] = upd["pos"][0]    # identical across layers
    elif kind == "ssm":
        def body(carry, scanned):
            h = carry
            lp, lc = scanned
            o, nc = ssm_mod.ssm_decode_step(
                cfg, lp["ssm"], norm_apply(cfg, lp["ln1"], h), lc)
            return h + o, nc
        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache["layers"] = new_layers
    else:  # hybrid
        shared = params["shared_attn"]
        pos_tags = cache["pos"]

        def group(carry, scanned):
            h = carry
            gp, gssm, gattn = scanned

            def inner(c, s):
                lp, lc = s
                o, nc = ssm_mod.ssm_decode_step(
                    cfg, lp["ssm"], norm_apply(cfg, lp["ln1"], c), lc)
                return c + o, nc
            h, ncs = jax.lax.scan(inner, h, (gp, gssm))
            hn = norm_apply(cfg, shared["ln1"], h)
            a, upd = attn.decode_self_attention(
                cfg, shared["attn"], hn, gattn, index, pos_tags,
                window=window)
            h = h + a
            h = h + mlp_apply(cfg, shared["mlp"],
                              norm_apply(cfg, shared["ln2"], h))
            return h, (ncs, {"k": upd["k"], "v": upd["v"],
                             "pos": upd["pos"]})

        x, (new_ssm, upd) = jax.lax.scan(
            group, x, (params["ssm_layers"], cache["ssm"], cache["attn"]))
        new_cache["ssm"] = new_ssm
        new_cache["attn"] = {"k": upd["k"], "v": upd["v"]}
        new_cache["pos"] = upd["pos"][0]
    new_cache["index"] = index + 1
    h = norm_apply(cfg, params["final_norm"], x)
    return logits_apply(cfg, params["tok"], h), new_cache


def _place(kv_s: jax.Array, cache_len: int) -> jax.Array:
    """Embed prefill KV (L,B,S,K,hd) at the head of a cache_len buffer."""
    l, b, s, k, hd = kv_s.shape
    if cache_len == s:
        return kv_s
    out = jnp.zeros((l, b, cache_len, k, hd), kv_s.dtype)
    return jax.lax.dynamic_update_slice(out, kv_s, (0, 0, 0, 0, 0))


def _pos_tags(s: int, cache_len: int) -> jax.Array:
    tags = jnp.full((cache_len,), -1, jnp.int32)
    return tags.at[:s].set(jnp.arange(s, dtype=jnp.int32))


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            *, window: int | None = None,
            cache_len: int | None = None) -> tuple[jax.Array, dict]:
    """Full-sequence prefill: logits + a cache ready for decode at index S.

    ``cache_len`` >= S reserves decode headroom (defaults to S, which makes
    the cache a ring that immediately starts evicting — pass the full
    expected context for exact decoding).
    """
    window = cfg.sliding_window if window is None else window
    kind = _block_kind(cfg)
    x = embed_tokens(cfg, params, batch)
    b, s, _ = x.shape
    cache_len = max(cache_len or s, s)
    cache = init_cache(cfg, b, cache_len)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if kind in ("dense", "moe"):
        def body(carry, lp):
            h = carry
            a, kv = attn.self_attention(cfg, lp["attn"],
                                        norm_apply(cfg, lp["ln1"], h),
                                        causal=True, window=window,
                                        positions=positions)
            h = h + a
            if "moe" in lp:
                m, _ = moe_mod.moe_block(cfg, lp["moe"],
                                         norm_apply(cfg, lp["ln2"], h))
            else:
                m = mlp_apply(cfg, lp["mlp"], norm_apply(cfg, lp["ln2"], h))
            return h + m, kv
        x, kvs = jax.lax.scan(body, x, params["layers"])
        cache["layers"] = jax.tree.map(lambda t: _place(t, cache_len), kvs)
        cache["pos"] = _pos_tags(s, cache_len)
    elif kind == "ssm":
        def body(carry, lp):
            h = carry
            hn = norm_apply(cfg, lp["ln1"], h)
            o, st = _ssm_block_with_state(cfg, lp["ssm"], hn)
            return h + o, st
        x, states = jax.lax.scan(body, x, params["layers"])
        cache["layers"] = states
    else:
        shared = params["shared_attn"]

        def group(carry, gp):
            h = carry

            def inner(c, lp):
                hn = norm_apply(cfg, lp["ln1"], c)
                o, st = _ssm_block_with_state(cfg, lp["ssm"], hn)
                return c + o, st
            h, sts = jax.lax.scan(inner, h, gp)
            a, kv = attn.self_attention(cfg, shared["attn"],
                                        norm_apply(cfg, shared["ln1"], h),
                                        causal=True, window=window,
                                        positions=positions)
            h = h + a
            h = h + mlp_apply(cfg, shared["mlp"],
                              norm_apply(cfg, shared["ln2"], h))
            return h, (sts, kv)
        x, (ssm_sts, kvs) = jax.lax.scan(group, x, params["ssm_layers"])
        cache["ssm"] = ssm_sts
        cache["attn"] = jax.tree.map(lambda t: _place(t, cache_len), kvs)
        cache["pos"] = _pos_tags(s, cache_len)

    cache["index"] = jnp.asarray(s, jnp.int32)
    if cfg.family == "vlm":
        x = x[:, cfg.num_patches:]
    h = norm_apply(cfg, params["final_norm"], x)
    return logits_apply(cfg, params["tok"], h), cache


def _ssm_block_with_state(cfg, p, u):
    """Like ssm_mod.ssm_block but also returns the decode cache."""
    from .ssm import _causal_conv, _dims, _split_conv, _split_proj
    from .layers import dense_apply, rms_norm
    d_in, heads, n, g, conv_ch, _ = _dims(cfg)
    bsz, l, _ = u.shape
    zxbcdt = dense_apply(p["in_proj"], u)
    z, xc_raw, dt = _split_proj(cfg, zxbcdt)
    xc = _causal_conv(p["conv_w"], p["conv_b"], xc_raw)
    x, b_mat, c_mat = _split_conv(cfg, xc)
    x = x.reshape(bsz, l, heads, cfg.ssm_headdim)
    b_mat = b_mat.reshape(bsz, l, g, n)
    c_mat = c_mat.reshape(bsz, l, g, n)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    from ..kernels import ops
    y, hT = ops.ssd(x, dtf, a, b_mat, c_mat, chunk=cfg.ssm_chunk)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, l, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = dense_apply(p["out_proj"], y)
    conv_tail = xc_raw[:, -(cfg.ssm_conv - 1):, :]
    return out, {"conv": conv_tail, "state": hT}
