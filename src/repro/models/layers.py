"""Shared neural-net building blocks (pure functional, dict params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.ctx import shard_act


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ norms

def norm_init(cfg: ModelConfig, dim: int) -> dict:
    p = {"scale": jnp.ones((dim,), pdtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), pdtype_of(cfg))
    return p


def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ linear

def dense_init(key, cfg: ModelConfig, din: int, dout: int,
               bias: bool = False, scale: float | None = None) -> dict:
    std = scale if scale is not None else din ** -0.5
    p = {"w": (jax.random.normal(key, (din, dout)) * std).astype(
        pdtype_of(cfg))}
    if bias:
        p["b"] = jnp.zeros((dout,), pdtype_of(cfg))
    return p


def dense_apply(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ------------------------------------------------------------------ MLP

def mlp_init(key, cfg: ModelConfig, d: int, f: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_in": dense_init(k1, cfg, d, f, bias=cfg.attn_bias and
                            cfg.family == "encdec"),
         "w_out": dense_init(k2, cfg, f, d)}
    if cfg.activation in ("silu", "geglu"):   # gated
        p["w_gate"] = dense_init(k3, cfg, d, f)
    return p


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    h = dense_apply(p["w_in"], x)
    if cfg.activation == "silu":
        h = jax.nn.silu(dense_apply(p["w_gate"], x)) * h
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(dense_apply(p["w_gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    h = shard_act(h, ("batch", "seq", "ffn"))
    return dense_apply(p["w_out"], h)


# ------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, rot_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2,
                                       dtype=jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               style: str = "full") -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int. style full|half|none.

    ``half`` is ChatGLM's 2d RoPE: only the first head_dim/2 channels
    rotate, the rest pass through.
    """
    if style == "none":
        return x
    hd = x.shape[-1]
    rot = hd if style == "full" else hd // 2
    freqs = rope_freqs(hd, rot, theta)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out


# ------------------------------------------------------------------ embed

def embed_init(key, cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    p = {"embed": (jax.random.normal(key, (v, cfg.d_model)) *
                   cfg.d_model ** -0.5).astype(pdtype_of(cfg))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"] = (jax.random.normal(k2, (cfg.d_model, v)) *
                     cfg.d_model ** -0.5).astype(pdtype_of(cfg))
    return p


def embed_apply(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["embed"].astype(dtype_of(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard_act(x, ("batch", "seq", "embed"))


def logits_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ p["embed"].astype(x.dtype).T
    else:
        logits = x @ p["head"].astype(x.dtype)
    logits = shard_act(logits, ("batch", "seq", "vocab"))
    # mask padded vocab entries
    v = cfg.padded_vocab
    if v != cfg.vocab_size:
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return logits
