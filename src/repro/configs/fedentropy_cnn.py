"""The paper's own model: LeNet-style CNN (FedEntropy Appendix Table 5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="fedentropy-cnn", family="cnn",
    num_layers=2, d_model=84, d_ff=120, vocab_size=10,
    param_dtype="float32", dtype="float32", remat="none",
    source="FedEntropy (Ling et al., 2022), Appendix Table 5",
)
