"""granite-8b — llama-arch dense code model [arXiv:2405.04324]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=49152,
    activation="silu", rope_theta=1e4,
    norm="rmsnorm", tie_embeddings=False,
    source="Granite Code Models [arXiv:2405.04324]",
)
