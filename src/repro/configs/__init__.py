"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""
from .base import SHAPES, ModelConfig, ShapeConfig

from . import (
    chatglm3_6b,
    fedentropy_cnn,
    gemma_7b,
    granite_8b,
    internvl2_1b,
    kimi_k2_1t_a32b,
    mamba2_130m,
    qwen3_0_6b,
    qwen3_moe_235b_a22b,
    whisper_large_v3,
    zamba2_2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mamba2_130m, whisper_large_v3, qwen3_0_6b, granite_8b,
        internvl2_1b, gemma_7b, zamba2_2_7b, qwen3_moe_235b_a22b,
        chatglm3_6b, kimi_k2_1t_a32b, fedentropy_cnn,
    )
}

# the 10 assigned architectures (excludes the paper's own CNN)
ASSIGNED = [n for n in ARCHS if n != "fedentropy-cnn"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]
