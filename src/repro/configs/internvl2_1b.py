"""internvl2-1b — VLM: InternViT frontend (stub) + Qwen2-0.5B-style LM
[arXiv:2404.16821]. input_specs feeds 256 precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, num_patches=256,
    activation="silu", attn_bias=True, rope_theta=1e6,
    norm="rmsnorm", tie_embeddings=True,
    source="InternVL2 [arXiv:2404.16821]; LM tower = Qwen2-0.5B",
)
