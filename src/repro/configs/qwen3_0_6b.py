"""qwen3-0.6b — dense, GQA kv=8, qk_norm, explicit head_dim=128
[hf:Qwen/Qwen3-8B family card]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=128, d_ff=3072, vocab_size=151936,
    activation="silu", qk_norm=True, rope_theta=1e6,
    norm="rmsnorm", tie_embeddings=True,
    source="Qwen3 [hf:Qwen/Qwen3-8B]",
)
