"""zamba2-2.7b — hybrid: Mamba2 backbone + SHARED attention block every 6th
layer [arXiv:2411.15242]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    attn_every=6, shared_attention=True,
    activation="gelu", norm="rmsnorm", tie_embeddings=True,
    source="Zamba2 [arXiv:2411.15242]",
)
