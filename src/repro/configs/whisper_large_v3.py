"""whisper-large-v3 — enc-dec audio; conv/mel frontend is a stub
[arXiv:2212.04356]. 32 encoder + 32 decoder layers, d=1280, 20 heads (MHA),
d_ff=5120, vocab 51866, 1500 encoder frames (30 s audio)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, num_encoder_layers=32, encoder_seq=1500,
    d_model=1280, num_heads=20, num_kv_heads=20, d_ff=5120,
    vocab_size=51866, activation="gelu", attn_bias=True,
    rope_style="none", norm="layernorm", tie_embeddings=True,
    source="Robust Speech Recognition via Large-Scale Weak Supervision "
           "[arXiv:2212.04356], large-v3 card",
)
