"""chatglm3-6b — dense, 2d (half-rotary) RoPE, extreme GQA kv=2
[arXiv:2406.12793]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    head_dim=128, d_ff=13696, vocab_size=65024,
    activation="silu", attn_bias=True, rope_style="half",
    norm="rmsnorm", tie_embeddings=False,
    source="ChatGLM [arXiv:2406.12793], chatglm3-6b card",
)
