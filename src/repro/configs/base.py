"""Config system: architecture and input-shape dataclasses + registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 0
    activation: str = "silu"         # silu | geglu | gelu
    attn_bias: bool = False
    qk_norm: bool = False
    rope_style: str = "full"         # full | half (chatglm 2d) | none
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: embeddings * sqrt(d_model)
    # --- MoE ---------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba2 / SSD) -------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    # --- hybrid (zamba2) ------------------------------------------------
    attn_every: int = 0              # every k-th layer is an attention block
    shared_attention: bool = False   # the attention block weights are shared
    # --- enc-dec (whisper) ----------------------------------------------
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frontend frames
    # --- VLM --------------------------------------------------------------
    num_patches: int = 0             # precomputed patch embeddings
    # --- attention variants ------------------------------------------------
    sliding_window: int = 0          # 0 = full attention
    # --- numerics / memory ---------------------------------------------
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    # --- provenance ------------------------------------------------------
    source: str = ""

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.num_heads and not self.num_kv_heads:
            object.__setattr__(self, "num_kv_heads", self.num_heads)

    # rounded-up vocab so TP over 16/256 lanes always divides
    @property
    def padded_vocab(self) -> int:
        mult = 256
        return ((self.vocab_size + mult - 1) // mult) * mult

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim if self.ssm_state else 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=2 if not self.attn_every else 2 * max(
                self.attn_every, 1),
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 512),
            d_ff=min(self.d_ff, 384) if self.d_ff else 0,
            param_dtype="float32", dtype="float32", remat="none",
        )
        if self.num_heads:
            kw["num_heads"] = min(self.num_heads, 4)
            kw["num_kv_heads"] = min(self.num_kv_heads, 2)
            kw["head_dim"] = 32
        if self.num_experts:
            kw["num_experts"] = 4
            kw["experts_per_token"] = 2
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 32)
            kw["ssm_headdim"] = 32
            kw["ssm_chunk"] = 16
        if self.num_encoder_layers:
            kw["num_encoder_layers"] = 2
            kw["encoder_seq"] = 32
        if self.num_patches:
            kw["num_patches"] = 8
        if self.attn_every:
            kw["attn_every"] = 2          # pattern [ssm, attn] x 2
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
