"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    ssm_conv=4, ssm_ngroups=1,
    norm="rmsnorm", tie_embeddings=True,
    source="Mamba-2: Transformers are SSMs [arXiv:2405.21060], 130m card",
)
