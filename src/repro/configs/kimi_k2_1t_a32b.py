"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 (paper-table)
[arXiv:2501.kimi2]. Spec'd here with GQA kv=8 per the assignment (the real
model uses MLA; the assignment pins GQA)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=2048, vocab_size=163840,
    num_experts=384, experts_per_token=8,
    activation="silu", rope_theta=5e4,
    norm="rmsnorm", tie_embeddings=False,
    source="Kimi K2 [arXiv:2501.kimi2] (paper-table trillion-param MoE)",
)
