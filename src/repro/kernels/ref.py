"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* — kernels must match them (tests sweep shapes and
dtypes and assert allclose). They are also the XLA fallback used by model
code on non-TPU backends and in the dry-run lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)  K | H
    v: jax.Array,            # (B, T, K, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unlimited
    q_offset: jax.Array | int = 0,   # global position of q[0] (decode)
    kv_positions: jax.Array | None = None,  # (B, T) global pos per kv slot,
                                            # -1 = invalid (ring buffers)
    scale: float | None = None,
) -> jax.Array:
    """Grouped-query attention with causal/sliding-window masking."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5 if scale is None else scale

    qq = q.reshape(b, s, kh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qq.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale

    q_pos = jnp.arange(s)[None, :] + jnp.atleast_1d(
        jnp.asarray(q_offset)).reshape(-1, 1)                      # (1|B, S)
    if kv_positions is None:
        kv_pos = jnp.arange(t)[None, :]                            # (1, T)
        valid = jnp.ones((1, t), bool)
    else:
        kv_pos = kv_positions
        valid = kv_pos >= 0
    mask = valid[:, None, :]                                       # (B,1,T)
    if causal:
        mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def mha_blockwise(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)
    v: jax.Array,            # (B, T, K, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_positions: jax.Array | None = None,
    scale: float | None = None,
    block_k: int = 512,
) -> jax.Array:
    """Flash-style blockwise attention in pure XLA (lax.scan over k-blocks
    with an online softmax). Numerically equivalent to ``mha_reference``
    but never materializes the (S, T) score matrix — peak attention
    activations drop from O(S*T) to O(S*block_k). Each scan step is
    rematerialized (jax.checkpoint) so the backward pass recomputes block
    scores flash-style instead of saving them.

    This is the §Perf "beyond-paper" memory optimization and doubles as
    the XLA twin of the Pallas flash_attention kernel (same math, same
    blocking), so TPU deployments get the kernel and everything else gets
    this.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5 if scale is None else scale
    block_k = min(block_k, t)
    pad = (block_k - t % block_k) % block_k
    nb = (t + pad) // block_k

    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_pos = jnp.arange(s)[None, :] + jnp.atleast_1d(
        jnp.asarray(q_offset)).reshape(-1, 1)              # (1|B, S)
    if kv_positions is None:
        kv_pos_full = jnp.broadcast_to(jnp.arange(t)[None], (1, t))
    else:
        kv_pos_full = kv_positions
    kv_pad = jnp.pad(kv_pos_full, ((0, 0), (0, pad)),
                     constant_values=-1)

    qq = (q.reshape(b, s, kh, g, d).astype(jnp.float32) * scale)

    def block(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, posb = inp                                 # (B|1? ...)
        sc = jnp.einsum("bskgd,btkd->bkgst", qq,
                        kb.astype(jnp.float32))            # (B,K,G,S,bk)
        valid = posb >= 0
        mask = valid[:, None, :]
        if causal:
            mask = mask & (posb[:, None, :] <= q_pos[:, :, None])
        if window:
            mask = mask & (posb[:, None, :] > q_pos[:, :, None] - window)
        sc = jnp.where(mask[:, None, None, :, :], sc, -1e30)
        m_cur = jnp.max(sc, axis=-1)                       # (B,K,G,S)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bskgd", p, vb.astype(jnp.float32)
        ).transpose(0, 2, 3, 1, 4)
        return (m_new, l_new, acc), None

    kb = jnp.moveaxis(kp.reshape(b, nb, block_k, kh, d), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nb, block_k, kh, d), 1, 0)
    posb = jnp.moveaxis(
        jnp.broadcast_to(kv_pad, (b, nb * block_k)).reshape(
            b, nb, block_k), 1, 0)
    init = (jnp.full((b, kh, g, s), -1e30, jnp.float32),
            jnp.zeros((b, kh, g, s), jnp.float32),
            jnp.zeros((b, kh, g, s, d), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(jax.checkpoint(block), init,
                                      (kb, vb, posb))
    out = acc / jnp.clip(l_f, 1e-30, None)[..., None]      # (B,K,G,S,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    return out.astype(q.dtype)


def ssd_reference(
    x: jax.Array,        # (B, L, H, P)   inputs per head
    dt: jax.Array,       # (B, L, H)      discretization steps (post-softplus)
    a: jax.Array,        # (H,)           negative decay rates (A = -exp(A_log))
    b_mat: jax.Array,    # (B, L, G, N)   input projections ("B" of SSM)
    c_mat: jax.Array,    # (B, L, G, N)   output projections ("C")
    *,
    init_state: jax.Array | None = None,   # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential (exact) SSD recurrence — the oracle for the chunked kernel.

    h_t = exp(dt_t a) h_{t-1} + dt_t * x_t outer b_t ;  y_t = h_t . c_t
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    g = b_mat.shape[2]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2)           # (B, L, H, N)
    ch = jnp.repeat(c_mat, rep, axis=2)
    decay = jnp.exp(dt * a[None, None, :])        # (B, L, H)

    def step(hstate, t):
        dx = (dt[:, t, :, None] * x[:, t]).astype(jnp.float32)   # (B,H,P)
        upd = dx[..., :, None] * bh[:, t, :, None, :]            # (B,H,P,N)
        hstate = decay[:, t, :, None, None] * hstate + upd
        y = jnp.einsum("bhpn,bhn->bhp", hstate, ch[:, t])
        return hstate, y

    h0 = (jnp.zeros((bsz, h, p, b_mat.shape[-1]), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0, jnp.arange(l))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)    # (B, L, H, P)
    return y, hT


def ssd_chunked_reference(
    x: jax.Array,        # (B, L, H, P)
    dt: jax.Array,       # (B, L, H)
    a: jax.Array,        # (H,)
    b_mat: jax.Array,    # (B, L, G, N)
    c_mat: jax.Array,    # (B, L, G, N)
    *,
    chunk: int = 256,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel SSD (Mamba2 Sec. 6): quadratic intra-chunk part +
    sequential inter-chunk state scan. Equivalent to ``ssd_reference`` but
    O(L/Q) sequential steps instead of O(L). This is the XLA production path
    and the blueprint the Pallas kernel tiles.
    """
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = min(chunk, l)
    if l % q:   # pad tail with dt=0 steps (decay=1, zero update): exact
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, h_t = ssd_chunked_reference(x, dt, a, b_mat, c_mat, chunk=q,
                                       init_state=init_state)
        return y[:, :l], h_t
    c = l // q
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2).reshape(bsz, c, q, h, n)
    ch = jnp.repeat(c_mat, rep, axis=2).reshape(bsz, c, q, h, n)
    xg = x.reshape(bsz, c, q, h, p)
    dtg = dt.reshape(bsz, c, q, h).astype(jnp.float32)
    adt = dtg * a[None, None, None, :]                     # log decays
    cums = jnp.cumsum(adt, axis=2)                          # (B,C,Q,H)

    # ---- intra-chunk (quadratic) --------------------------------------
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (B,C,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    dtx = dtg[..., None] * xg.astype(jnp.float32)           # (B,C,Q,H,P)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", ch.astype(jnp.float32),
                    bh.astype(jnp.float32))
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", cb * lmat, dtx)

    # ---- chunk summary states ------------------------------------------
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)       # (B,C,Q,H)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_to_end,
                        bh.astype(jnp.float32), dtx)        # (B,C,H,P,N)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                # (B,C,H)

    # ---- inter-chunk recurrence (sequential over C chunks) --------------
    def step(hstate, inp):
        s, dec = inp
        prev = hstate
        hstate = dec[..., None, None] * hstate + s
        return hstate, prev

    h0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    h_t, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,C,H,P,N)

    # ---- inter-chunk contribution ----------------------------------------
    decay_in = jnp.exp(cums)                                # (B,C,Q,H)
    y_off = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp", decay_in,
                       ch.astype(jnp.float32), h_prevs)
    y = (y_diag + y_off).reshape(bsz, l, h, p).astype(x.dtype)
    return y, h_t


def entropy_judge_sweep_reference(
    soft_labels: jax.Array,   # (M, C)
    sizes: jax.Array,         # (M,)
    mask: jax.Array,          # (M,)
) -> tuple[jax.Array, jax.Array]:
    """(group_entropy, leave-one-out entropies (M,)) — oracle for the
    entropy_judge kernel; mirrors core.entropy.leave_one_out_entropies."""
    from ..core.entropy import group_entropy, leave_one_out_entropies
    return (group_entropy(soft_labels, sizes, mask),
            leave_one_out_entropies(soft_labels, sizes, mask))


def masked_weighted_sum_reference(
    flat: jax.Array,      # (M, P)
    weights: jax.Array,   # (M,)
) -> jax.Array:
    """(P,) = sum_i weights[i] * flat[i, :] — oracle for the fused
    aggregation kernel (one fused-jnp reduction over the client axis)."""
    w = jnp.asarray(weights, jnp.float32)
    return jnp.sum(flat.astype(jnp.float32) * w[:, None], axis=0)
