"""Fused masked weighted aggregation kernel (Pallas, TPU target).

Paper Alg. 2 line 21 runs one weighted mean per pytree leaf — for an
LM-sized model that is hundreds of small reductions per round. Here the
whole flattened parameter buffer (M clients x P params, padded to tile
multiples) streams through VMEM in (block_m, block_p) tiles, reduced
over the client axis against the (M,) weight vector in a single kernel
launch: a segment-reduce with one segment per parameter column.

The grid is 2-D, (param tiles, client tiles) with the client index
innermost: each output block is revisited across the client tiles of its
column (the revisited dim must be the fastest-varying one), zero-
initialized on the first visit (``pl.when(mi == 0)``) and accumulated in
float32 on the rest — which is what lets an LM-sized P and a large
cohort M both stay inside a fixed VMEM budget instead of forcing an
(M, block_p) resident stripe. Tile sizes derive from
``vmem_budget_bytes`` (double-buffered f32 tile + weights slice),
``block_p`` clamped to lane multiples of 128.

The weights already fold ``sizes * mask`` (masked-out clients carry
weight 0) and padding rows/columns are zero, so no in-kernel masking is
needed — padded sums are 0 and are sliced off by the caller. Low-
precision (bf16) leaves are cast to f32 by the caller *before* the
flatten, so in-kernel accumulation is always f32 — the same
accumulate-dtype contract as ``core.aggregation.masked_mean_tree``.

Validated against ref.masked_weighted_sum_reference in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128


def _fused_kernel(x_ref, w_ref, out_ref):
    mi = pl.program_id(1)          # innermost: client tiles of one column

    @pl.when(mi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bp)
    w = w_ref[...].astype(jnp.float32)            # (bm,)
    out_ref[...] += jnp.sum(x * w[:, None], axis=0)


def _plan_tiles(m: int, p: int, block_p: int,
                vmem_budget_bytes: int) -> tuple[int, int]:
    """(block_m, block_p) so a double-buffered f32 tile fits the budget."""
    bp = min(block_p, -(-p // _LANE) * _LANE)
    bp = max(_LANE, (bp // _LANE) * _LANE)

    def rows(bp_):
        return max(1, vmem_budget_bytes // (2 * 4 * bp_))

    # narrow the column tile until at least a few client rows fit
    while bp > _LANE and rows(bp) < min(m, 8):
        bp = max(_LANE, (bp // 2 // _LANE) * _LANE)
    return min(m, rows(bp)), bp


def masked_weighted_sum(
    flat: jax.Array,     # (M, P) flattened client params, float32
    weights: jax.Array,  # (M,) sizes * mask, float32
    *,
    block_p: int = 2048,
    block_m: int | None = None,
    vmem_budget_bytes: int = 4 * 1024 * 1024,
    interpret: bool = True,
) -> jax.Array:
    """Returns (P,) = sum_i weights[i] * flat[i, :] in one tiled pass."""
    m, p = flat.shape
    w = jnp.asarray(weights, jnp.float32)
    bm, bp = _plan_tiles(m, max(p, 1), block_p, vmem_budget_bytes)
    if block_m is not None:
        bm = min(int(block_m), m)
    pad_p = (bp - p % bp) % bp
    pad_m = (bm - m % bm) % bm
    x = flat
    if pad_p or pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, pad_p)))
    if pad_m:
        w = jnp.pad(w, (0, pad_m))      # zero weight: padded rows sum to 0
    np_ = x.shape[1] // bp
    nm = x.shape[0] // bm

    out = pl.pallas_call(
        _fused_kernel,
        grid=(np_, nm),                 # mi innermost: out block revisited
        in_specs=[
            pl.BlockSpec((bm, bp), lambda pi, mi: (mi, pi)),
            pl.BlockSpec((bm,), lambda pi, mi: (mi,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda pi, mi: (pi,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out[:p]
