"""Fused masked weighted aggregation kernel (Pallas, TPU target).

Paper Alg. 2 line 21 runs one weighted mean per pytree leaf — for an
LM-sized model that is hundreds of small reductions per round. Here the
whole flattened parameter buffer (M clients x P params, padded to a tile
multiple) streams through VMEM in ``block_p``-wide tiles, each tile
reduced over the client axis against the (M,) weight vector in a single
kernel launch: a segment-reduce with one segment per parameter column.

The weights already fold ``sizes * mask`` (masked-out clients carry
weight 0) and padding columns are zero, so no in-kernel masking is
needed — padded sums are 0 and are sliced off by the caller.

VMEM per step: (M, block_p) tile + (M,) weights ~= 10*2048*4 B ~= 80 KiB.

Validated against ref.masked_weighted_sum_reference in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, w_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)            # (M, bp)
    w = w_ref[...].astype(jnp.float32)            # (M,)
    out_ref[...] = jnp.sum(x * w[:, None], axis=0)


def masked_weighted_sum(
    flat: jax.Array,     # (M, P) flattened client params, float32
    weights: jax.Array,  # (M,) sizes * mask, float32
    *,
    block_p: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """Returns (P,) = sum_i weights[i] * flat[i, :] in one tiled pass."""
    m, p = flat.shape
    w = jnp.asarray(weights, jnp.float32)
    block_p = min(block_p, max(p, 1))
    pad = (block_p - p % block_p) % block_p
    x = flat
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    np_ = x.shape[1] // block_p

    out = pl.pallas_call(
        _fused_kernel,
        grid=(np_,),
        in_specs=[
            pl.BlockSpec((m, block_p), lambda pi: (0, pi)),
            pl.BlockSpec((m,), lambda pi: (0,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda pi: (pi,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out[:p]
