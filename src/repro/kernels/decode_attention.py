"""Single-query (decode) flash attention Pallas kernel — TPU target.

The decode hot spot: one new token attends to a long position-tagged KV
cache (ring buffers carry slot tags; -1 = empty). Grid (batch, q_heads,
k_blocks): the k axis streams cache blocks of (block_k, head_dim) through
VMEM while the online-softmax accumulator for the single query row lives
in scratch — HBM traffic is exactly one pass over the cache, which is the
roofline lower bound for decode.

Validated against ref.mha_reference (S=1) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, tag_ref, idx_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, window: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32) * scale     # (d,)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    tags = tag_ref[0]                                  # (bk,) int32
    index = idx_ref[0]                                 # () current position

    s = k @ q                                          # (bk,)
    mask = (tags >= 0) & (tags <= index)
    if window:
        mask &= tags > index - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = alpha * l_ref[0] + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[0] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_ref[...] / jnp.clip(
            l_ref[0], 1e-30, None)).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,             # (B, 1, H, D)
    k: jax.Array,             # (B, T, KH, D) cache
    v: jax.Array,             # (B, T, KH, D)
    kv_positions: jax.Array,  # (B, T) int32 slot tags, -1 = empty
    index: jax.Array,         # () int32 current decode position
    *,
    window: int = 0,
    scale: float | None = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5 if scale is None else scale
    block_k = min(block_k, t)
    pad = (block_k - t % block_k) % block_k
    nk = (t + pad) // block_k

    kt = jnp.moveaxis(k, 2, 1)                          # (B, KH, T, D)
    vt = jnp.moveaxis(v, 2, 1)
    qt = jnp.moveaxis(q, 2, 1)                          # (B, H, 1, D)
    tags = kv_positions
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        tags = jnp.pad(tags, ((0, 0), (0, pad)), constant_values=-1)

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_decode_kernel, scale=scale, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1,), lambda bi, hi, ki: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, tags, jnp.asarray(index, jnp.int32)[None])
    return jnp.moveaxis(out, 1, 2)                      # (B, 1, H, D)
