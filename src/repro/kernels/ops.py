"""Jit'd public wrappers for the kernel package with backend dispatch.

backend="xla"     — pure-jnp reference implementations (CPU, dry-run).
backend="pallas"  — Pallas TPU kernels (validated on CPU via interpret=True;
                    Mosaic-lowered on real TPUs).

``set_default_backend`` flips the global default (used by tests and by the
launcher's --kernels flag).
"""
from __future__ import annotations


from . import ref

_DEFAULT = "xla"
_INTERPRET = True  # no TPU in this container; real deployments set False


def set_default_backend(name: str, interpret: bool | None = None) -> None:
    global _DEFAULT, _INTERPRET
    assert name in ("xla", "pallas", "blockwise")
    _DEFAULT = name
    if interpret is not None:
        _INTERPRET = interpret


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              kv_positions=None, scale=None, backend=None):
    backend = backend or _DEFAULT
    if backend == "pallas" and q.shape[1] == 1 and kv_positions is not None:
        from .decode_attention import decode_attention
        import jax.numpy as jnp
        idx = jnp.max(kv_positions)   # current position = newest slot tag
        return decode_attention(q, k, v, kv_positions, idx, window=window,
                                scale=scale, interpret=_INTERPRET)
    if backend == "pallas" and q.shape[1] > 1:
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, interpret=_INTERPRET)
    if backend == "blockwise" and k.shape[1] > 512:
        return ref.mha_blockwise(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset,
                                 kv_positions=kv_positions, scale=scale)
    return ref.mha_reference(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_positions=kv_positions,
                             scale=scale)


def ssd(x, dt, a, b_mat, c_mat, *, chunk=256, init_state=None, backend=None):
    backend = backend or _DEFAULT
    if backend == "pallas":
        from .ssd_scan import ssd_chunked
        return ssd_chunked(x, dt, a, b_mat, c_mat, chunk=chunk,
                           init_state=init_state, interpret=_INTERPRET)
    if x.shape[1] == 1:   # single-token: exact sequential step
        return ref.ssd_reference(x, dt, a, b_mat, c_mat,
                                 init_state=init_state)
    return ref.ssd_chunked_reference(x, dt, a, b_mat, c_mat, chunk=chunk,
                                     init_state=init_state)


def entropy_judge_sweep(soft_labels, sizes, mask, *, backend=None):
    backend = backend or _DEFAULT
    if backend == "pallas":
        from .entropy_judge import entropy_judge_sweep
        return entropy_judge_sweep(soft_labels, sizes, mask,
                                   interpret=_INTERPRET)
    return ref.entropy_judge_sweep_reference(soft_labels, sizes, mask)


def masked_weighted_sum(flat, weights, *, backend=None, block_p=2048,
                        vmem_budget_bytes=4 * 1024 * 1024):
    backend = backend or _DEFAULT
    if backend == "pallas":
        from .fused_aggregate import masked_weighted_sum
        return masked_weighted_sum(
            flat, weights, block_p=block_p,
            vmem_budget_bytes=vmem_budget_bytes, interpret=_INTERPRET)
    return ref.masked_weighted_sum_reference(flat, weights)
