"""Mamba2 SSD chunk kernel (Pallas, TPU target).

One grid step processes one (batch, head, chunk) tile entirely in VMEM:
intra-chunk quadratic part, inter-chunk state contribution, and the running
state update. The chunk axis is the innermost grid dimension — TPU executes
it sequentially, so the (P, N) recurrent state lives in VMEM scratch across
chunk iterations (the inter-chunk scan is thereby FUSED into the kernel
instead of being a separate lax.scan at the ops layer).

VMEM working set per step: x (Q,P) + b,c (Q,N) + L (Q,Q) + state (P,N) in
f32 ~= (256*64 + 2*256*128 + 256^2 + 64*128) * 4 B ~= 0.6 MiB with the
default Q=256, P=64, N=128 — MXU-aligned and far inside budget.

Validated against ref.ssd_reference (sequential oracle) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                h_ref, *, chunk: int, seq_len: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)             # ()
    bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    # mask padded tail steps: dt=0 -> decay 1, zero update
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = (ci * chunk + q_idx) < seq_len
    dt = jnp.where(valid, dt, 0.0)

    adt = dt * a                                  # (Q,) log-decays
    cums = jnp.cumsum(adt)                        # (Q,)

    # intra-chunk: L[i,j] = exp(cums_i - cums_j) for j <= i
    seg = cums[:, None] - cums[None, :]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
              jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    lmat = jnp.where(causal, jnp.exp(seg), 0.0)
    dtx = dt[:, None] * x                         # (Q, P)
    cb = cm @ bm.T                                # (Q, Q) scores
    y = (cb * lmat) @ dtx                         # (Q, P)

    # inter-chunk contribution from the carried state
    h = h_ref[...]                                # (P, N)
    y += jnp.exp(cums)[:, None] * (cm @ h.T)

    # state update: h' = exp(cums[-1]) h + sum_j exp(cums[-1]-cums_j) dtx_j b_j
    decay_to_end = jnp.exp(cums[-1] - cums)       # (Q,)
    h_ref[...] = jnp.exp(cums[-1]) * h + \
        (decay_to_end[:, None] * dtx).T @ bm      # (P, N)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0] = h_ref[...]


def ssd_chunked(
    x: jax.Array,        # (B, L, H, P)
    dt: jax.Array,       # (B, L, H)   post-softplus
    a: jax.Array,        # (H,)
    b_mat: jax.Array,    # (B, L, G, N)
    c_mat: jax.Array,    # (B, L, G, N)
    *,
    chunk: int = 256,
    init_state=None,     # kernel path requires zero init (assert below)
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    assert init_state is None, "ssd_chunked kernel assumes zero init state"
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    pad = (q - l % q) % q
    nc = (l + pad) // q

    xt = jnp.moveaxis(x, 2, 1)                        # (B, H, L, P)
    dtt = jnp.moveaxis(dt, 2, 1)                      # (B, H, L)
    bt = jnp.moveaxis(b_mat, 2, 1)                    # (B, G, L, N)
    ct = jnp.moveaxis(c_mat, 2, 1)
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
        bt = jnp.pad(bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=q, seq_len=l)
    from jax.experimental.pallas import tpu as pltpu
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, q), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, hi, ci: (bi, hi // rep, ci, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bi, hi, ci: (bi, hi // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xt.shape, x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a, bt, ct)
    if pad:
        y = y[:, :, :l, :]
    return jnp.moveaxis(y, 1, 2), state
