"""Maximum-entropy judgment sweep kernel (Pallas, TPU target).

THE paper's hot loop, expressed as a kernel: given per-device soft labels
P (M, C), sizes L (M,) and the active mask, compute in ONE streaming pass
over the class axis both

  * the weighted group entropy of the active set (Eq. 3/4), and
  * all M leave-one-out entropies (Alg. 1 lines 5-12, vectorized),

i.e. everything one greedy iteration of Algorithm 1 needs. The class axis
is tiled (block_c wide) so a 256k-class soft-label matrix streams through
VMEM while the (M+1,) entropy accumulators persist in scratch — the
judgment cost is O(M*C) per iteration with C never materialized in fp32
beyond one tile.

VMEM per step: (M, block_c) tile + (M+1,) accumulators ~= 32*512*4 B
~= 64 KiB.

Validated against ref.entropy_judge_sweep_reference in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


def _judge_kernel(p_ref, w_ref, tot_ref, den_ref, out_ref, acc_ref, *,
                  block_c: int, num_classes: int):
    ci = pl.program_id(0)
    nc = pl.num_programs(0)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = p_ref[...].astype(jnp.float32)            # (M, bc)
    w = w_ref[...].astype(jnp.float32)            # (M,)
    tot = tot_ref[0]                              # ()
    den = den_ref[...]                            # (M,) tot - w_k (>=eps)

    c_idx = ci * block_c + jax.lax.broadcasted_iota(
        jnp.int32, (p.shape[0], block_c), 1)
    valid = c_idx < num_classes
    pw = jnp.where(valid, p * w[:, None], 0.0)    # (M, bc)
    s = jnp.sum(pw, axis=0)                       # (bc,) weighted sum

    def plogp(q):
        return jnp.where(q > 0, q * jnp.log(jnp.maximum(q, _EPS)), 0.0)

    # group entropy contribution
    qg = s / jnp.maximum(tot, _EPS)
    acc_ref[0] += -jnp.sum(plogp(qg))

    # leave-one-out: q_k = (s - w_k p_k) / (tot - w_k)
    loo = (s[None, :] - pw) / den[:, None]
    acc_ref[1:] += -jnp.sum(plogp(loo), axis=1)

    @pl.when(ci == nc - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


def entropy_judge_sweep(
    soft_labels: jax.Array,    # (M, C)
    sizes: jax.Array,          # (M,)
    mask: jax.Array,           # (M,)
    *,
    block_c: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (group_entropy (), leave_one_out (M,)) matching
    core.entropy semantics (emptying removals -> -1.0)."""
    m, c = soft_labels.shape
    w = (jnp.asarray(sizes, jnp.float32) * jnp.asarray(mask, jnp.float32))
    tot = jnp.sum(w)
    den = jnp.maximum(tot - w, _EPS)

    block_c = min(block_c, c)
    pad = (block_c - c % block_c) % block_c
    p = soft_labels
    if pad:
        p = jnp.pad(p, ((0, 0), (0, pad)))
    nc = p.shape[1] // block_c

    kernel = functools.partial(_judge_kernel, block_c=block_c,
                               num_classes=c)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((m, block_c), lambda ci: (0, ci)),
            pl.BlockSpec((m,), lambda ci: (0,)),
            pl.BlockSpec((1,), lambda ci: (0,)),
            pl.BlockSpec((m,), lambda ci: (0,)),
        ],
        out_specs=pl.BlockSpec((m + 1,), lambda ci: (0,)),
        out_shape=jax.ShapeDtypeStruct((m + 1,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m + 1,), jnp.float32)],
        interpret=interpret,
    )(p, w, tot[None], den)

    ent = out[0]
    loo = jnp.where(tot - w > _EPS, out[1:], -1.0)
    # empty active set -> uniform/max-entropy convention of the reference
    ent = jnp.where(tot > 0, ent, jnp.log(jnp.asarray(c, jnp.float32)))
    return ent, loo
