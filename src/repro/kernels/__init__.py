from . import ops, ref
