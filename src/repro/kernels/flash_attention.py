"""Blockwise (flash) attention Pallas kernel — TPU target.

Online-softmax attention with causal and sliding-window masking and GQA
(q-head -> kv-head map folded into the BlockSpec index maps). Grid is
(batch, q_heads, q_blocks, k_blocks); the innermost k dimension executes
sequentially on TPU, so the running max / normalizer / accumulator live in
VMEM scratch across k iterations (MaxText-style). Block shapes are
MXU-aligned (block_q x head_dim and block_k x head_dim tiles in VMEM);
with block_q = block_k = 128 and head_dim <= 256 the working set is
~(2*128*256 + 128*128) * 4 B < 1 MiB — far inside the ~16 MiB VMEM budget,
leaving room for double buffering.

Validated against kernels.ref.mha_reference via interpret=True (tests sweep
shapes, dtypes, GQA ratios, windows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = q @ k.T                                          # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq,)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # renormalize previous accumulator
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.clip(l, 1e-30, None)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,           # (B, S, H, D)
    k: jax.Array,           # (B, T, KH, D)
    v: jax.Array,           # (B, T, KH, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, s)
    block_k = min(block_k, t)

    # pad seq dims to block multiples (masked out inside the kernel)
    s_pad = (block_q - s % block_q) % block_q
    t_pad = (block_k - t % block_k) % block_k
    qt = jnp.moveaxis(q, 2, 1)                           # (B, H, S, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=t)
    out = _call(kernel, qt, kt, vt, b, h, nq, nk, block_q,
                block_k, d, g, q.dtype, interpret)
    if s_pad:
        out = out[:, :, :s, :]
    return jnp.moveaxis(out, 1, 2)


def _call(kernel, qt, kt, vt, b, h, nq, nk, block_q, block_k, d, g,
          dtype, interpret):
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
