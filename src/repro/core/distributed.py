"""Mesh-scale FedEntropy: the paper's round as ONE pjit-able train step.

Cross-silo mapping (DESIGN.md §2.2): the global batch is tiled into M client
groups along the ("pod","data") mesh axes. With one local step (E=1), masked
FedAvg of per-client gradients is EXACTLY the gradient of the
mask-and-size-weighted loss — so the whole round fuses into a single
forward+backward:

  1. forward -> logits; per-client soft labels = mean softmax over the
     client's tokens (paper Eq. 2), under stop_gradient;
  2. maximum-entropy judgment (Alg. 1 as lax.while_loop) -> mask (M,);
  3. loss = sum_m mask_m * size_m * loss_m / sum_m mask_m * size_m
     (paper Alg. 2 line 21 at gradient level); backward reuses the
     forward's activations — zero extra passes.

Semantics note (recorded in DESIGN.md): the paper judges soft labels of the
*locally updated* models; at E=1 the update direction is the same gradient
being aggregated, so judging pre-update logits is the first-order-consistent
formulation. The vmapped simulator (core/simulator.py) keeps the exact
multi-epoch semantics for models that fit per-client. Soft labels stay
full-vocabulary (paper Eq. 2): V floats per client is negligible next to
model bytes, which is the paper's entire communication argument.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.api import Model
from ..optim import Optimizer
from ..sharding.ctx import shard_act
from .judgment import judge


@dataclass(frozen=True)
class FedSpec:
    num_clients: int = 16          # M client groups tiled over batch axes
    enabled: bool = True           # False -> plain data-parallel baseline
    eps_tol: float = 1e-6
    # §Perf: stream the vocab projection + CE + soft-label accumulation in
    # sequence chunks instead of materializing (B, S, V) logits.
    chunked_head: bool = False
    seq_chunk: int = 512


def chunked_head_stats(cfg: ModelConfig, tok_params: dict, h: jax.Array,
                       tokens: jax.Array, m: int, seq_chunk: int = 512
                       ) -> tuple[jax.Array, jax.Array]:
    """Per-client (loss (M,), soft labels (M, V)) without a full logits
    tensor: lax.scan over sequence chunks computes the vocab projection,
    next-token CE and softmax accumulation per chunk and discards the
    chunk logits. Peak head activations drop from O(B*S*V) to
    O(B*seq_chunk*V). Each chunk is rematerialized for the backward.
    """
    from ..models.layers import logits_apply
    b, s, d = h.shape
    v = cfg.padded_vocab
    sc = min(seq_chunk, s)
    pad = (sc - s % sc) % sc
    nb = (s + pad) // sc
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    hp = jnp.moveaxis(hp.reshape(b, nb, sc, d), 1, 0)      # (nb,B,sc,D)
    # target for position j is tokens[j+1]; weight 0 at j >= S-1
    tgt = jnp.pad(tokens[:, 1:], ((0, 0), (0, pad + 1)))
    tgt = jnp.moveaxis(tgt.reshape(b, nb, sc), 1, 0)
    base = jnp.arange(nb) * sc

    def chunk(carry, inp):
        nll_sum, soft_sum = carry
        hc, tc, b0 = inp
        logits = logits_apply(cfg, tok_params, hc).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        pos = b0 + jnp.arange(sc)[None, :]                 # (1, sc)
        wgt = (pos < s - 1).astype(jnp.float32)            # next-token mask
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum(
            (nll * wgt).reshape(m, -1), axis=1)
        probs = jax.lax.stop_gradient(jnp.exp(logp))
        svalid = (pos < s).astype(jnp.float32)             # Eq.2: all pos
        soft_sum = soft_sum + jnp.einsum(
            "mtv->mv", (probs * svalid[..., None]).reshape(m, -1, v))
        return (nll_sum, soft_sum), None

    init = (jnp.zeros((m,), jnp.float32), jnp.zeros((m, v), jnp.float32))
    (nll_sum, soft_sum), _ = jax.lax.scan(
        jax.checkpoint(chunk), init, (hp, tgt, base))
    per_client = nll_sum / ((s - 1) * (b // m))
    soft = soft_sum / (s * (b // m))
    return per_client, shard_act(soft, ("fl_clients", "vocab"))


def per_client_soft_labels(logits: jax.Array, m: int) -> jax.Array:
    """(B, S, V) -> (M, V) mean softmax per client group (paper Eq. 2)."""
    b, s, v = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    probs = probs.reshape(m, (b // m) * s, v)
    soft = jnp.mean(probs, axis=1)
    return shard_act(soft, ("fl_clients", "vocab"))


def _per_client_loss(cfg: ModelConfig, logits, tokens, m):
    """(M,) mean next-token CE per client group."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tg[..., None], axis=-1)[..., 0]
    b = nll.shape[0]
    return jnp.mean(nll.reshape(m, -1), axis=1)


def make_train_step(
    model: Model,
    opt: Optimizer,
    fed: FedSpec,
    judge_fn: Callable | None = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch`` needs "tokens" (+family extras) and optionally
    "client_sizes" (M,) — defaults to uniform.

    ``judge_fn`` is the traced judge axis: (soft_labels, sizes) ->
    ``JudgmentResult``. Defaults to the maximum-entropy judgment; pass a
    ``repro.fl`` judge's ``.traced()`` to run any registered judge (or the
    Pallas-backed sweep) inside the jitted step."""
    cfg = model.cfg
    if judge_fn is None:
        judge_fn = judge

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        m = fed.num_clients
        if fed.chunked_head:
            h, aux = model.hidden(params, batch)
            client_loss, soft = chunked_head_stats(
                cfg, params["tok"], h, tokens, m, fed.seq_chunk)
        else:
            logits, aux = model.forward(params, batch)
            client_loss = _per_client_loss(cfg, logits, tokens, m)  # (M,)
            soft = None
        sizes = batch.get(
            "client_sizes", jnp.ones((m,), jnp.float32))

        if fed.enabled:
            if soft is None:
                soft = per_client_soft_labels(
                    jax.lax.stop_gradient(logits), m)
            jr = judge_fn(soft, jax.lax.stop_gradient(sizes))
            mask = jax.lax.stop_gradient(jr.mask)
            ent, ent0 = jr.entropy, jr.initial_entropy
        else:
            mask = jnp.ones((m,), jnp.float32)
            ent = ent0 = jnp.zeros(())

        w = mask * sizes
        loss = jnp.sum(w * client_loss) / jnp.clip(jnp.sum(w), 1e-9)
        loss = loss + cfg.router_aux_weight * aux
        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "mask": mask,
            "num_positive": jnp.sum(mask),
            "entropy": ent,
            "entropy_initial": ent0,
            "per_client_loss": client_loss,
        }
        return loss, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = jax.grad(loss_fn, has_aux=True)(params, batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_state, metrics

    return train_step


def make_microbatched_train_step(
    model: Model,
    opt: Optimizer,
    fed: FedSpec,
    num_microbatches: int,
    judge_fn: Callable | None = None,
) -> Callable:
    """Two-phase microbatched FedEntropy round — the paper's two-stage
    protocol made literal, and the memory lever for models whose
    activations don't fit at full global batch (kimi-k2 train_4k):

    Phase 1 (paper stage 1): forward-only scan over microbatches
    accumulating per-client soft-label sums and losses; judge ONCE on the
    full-batch soft labels (identical mask to the unbatched step).
    Phase 2 (paper stage 2): gradient-accumulation scan over the same
    microbatches with the judged mask weighting each client's loss.

    Peak activation memory drops ~num_microbatches-fold; compute cost is
    one extra forward (phase 1), the classic remat-style trade.

    ``judge_fn`` as in :func:`make_train_step` — the same traced judge
    axis plugs into both step builders.
    """
    cfg = model.cfg
    if judge_fn is None:
        judge_fn = judge

    def _split(batch):
        def sp(x):
            b = x.shape[0]
            mb = b // num_microbatches
            # keep client interleaving: (B,) -> (n_mb, M, B/M/n_mb, ...)
            m = fed.num_clients
            per = b // m
            x2 = x.reshape(m, per, *x.shape[1:])
            x2 = x2.reshape(m, num_microbatches, per // num_microbatches,
                            *x.shape[1:])
            return jnp.moveaxis(x2, 1, 0).reshape(
                num_microbatches, m * (per // num_microbatches),
                *x.shape[1:])
        return jax.tree.map(sp, batch)

    def phase1(params, mbatches):
        m = fed.num_clients
        v = cfg.padded_vocab

        def body(carry, mb):
            soft_sum, loss_sum = carry
            logits, _ = model.forward(params, mb)
            soft = per_client_soft_labels(logits, m)
            loss = _per_client_loss(cfg, logits, mb["tokens"], m)
            return (soft_sum + soft, loss_sum + loss), None

        (soft_sum, loss_sum), _ = jax.lax.scan(
            body, (jnp.zeros((m, v), jnp.float32),
                   jnp.zeros((m,), jnp.float32)), mbatches)
        return soft_sum / num_microbatches, loss_sum / num_microbatches

    def train_step(params, opt_state, batch):
        m = fed.num_clients
        mbatches = _split(batch)
        sizes = jnp.ones((m,), jnp.float32)

        if fed.enabled:
            soft, _ = phase1(params, mbatches)
            jr = judge_fn(jax.lax.stop_gradient(soft), sizes)
            mask = jax.lax.stop_gradient(jr.mask)
            ent, ent0 = jr.entropy, jr.initial_entropy
        else:
            mask = jnp.ones((m,), jnp.float32)
            ent = ent0 = jnp.zeros(())

        w = mask * sizes

        def mb_loss(p, mb):
            logits, aux = model.forward(p, mb)
            client_loss = _per_client_loss(cfg, logits, mb["tokens"], m)
            loss = jnp.sum(w * client_loss) / jnp.clip(jnp.sum(w), 1e-9)
            return loss + cfg.router_aux_weight * aux, client_loss

        grad_fn = jax.grad(mb_loss, has_aux=True)

        def acc_body(carry, mb):
            g_acc, l_acc, cl_acc = carry
            g, cl = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
            loss = jnp.sum(w * cl) / jnp.clip(jnp.sum(w), 1e-9)
            return (g_acc, l_acc + loss, cl_acc + cl), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum, cl_sum), _ = jax.lax.scan(
            acc_body, (zeros, jnp.zeros(()), jnp.zeros((m,))), mbatches)
        grads = jax.tree.map(lambda g: g / num_microbatches, grads)

        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = {
            "loss": loss_sum / num_microbatches,
            "mask": mask,
            "num_positive": jnp.sum(mask),
            "entropy": ent,
            "entropy_initial": ent0,
            "per_client_loss": cl_sum / num_microbatches,
        }
        return new_params, new_state, metrics

    return train_step


def make_serve_steps(model: Model, *, window: int | None = None):
    """(prefill_step, decode_step) for the serving shapes."""
    def prefill_step(params, batch):
        return model.prefill(params, batch, window=window)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, window=window)

    return prefill_step, decode_step


# ---------------------------------------------------------------- specs

# logical axes for the trailing dims of each param, keyed by path suffix.
_PARAM_RULES: list[tuple[tuple[str, ...], tuple]] = [
    (("tok", "embed"), ("vocab", "embed")),
    (("tok", "head"), ("embed", "vocab")),
    (("patch_proj", "w"), ("embed", None)),
    (("attn", "w_q", "w"), ("embed", "heads")),
    (("attn", "w_k", "w"), ("embed", "kv_heads")),
    (("attn", "w_v", "w"), ("embed", "kv_heads")),
    (("attn", "w_o", "w"), ("heads", "embed")),
    (("xattn", "w_q", "w"), ("embed", "heads")),
    (("xattn", "w_k", "w"), ("embed", "kv_heads")),
    (("xattn", "w_v", "w"), ("embed", "kv_heads")),
    (("xattn", "w_o", "w"), ("heads", "embed")),
    (("mlp", "w_in", "w"), ("embed", "ffn")),
    (("mlp", "w_gate", "w"), ("embed", "ffn")),
    (("mlp", "w_out", "w"), ("ffn", "embed")),
    (("moe", "router", "w"), ("embed", "experts")),
    (("moe", "w_in"), ("experts", "embed", "ffn")),
    (("moe", "w_gate"), ("experts", "embed", "ffn")),
    (("moe", "w_out"), ("experts", "ffn", "embed")),
    (("ssm", "in_proj", "w"), ("embed", "ssm_inner")),
    (("ssm", "out_proj", "w"), ("ssm_inner", "embed")),
    (("ssm", "conv_w"), (None, "ssm_inner")),
    (("ssm", "conv_b"), ("ssm_inner",)),
    (("ssm", "norm_scale"), ("ssm_inner",)),
]


def _path_names(path) -> tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_logical_axes(params_shape) -> Any:
    """Tree of logical-axis tuples matching ``jax.eval_shape(init)`` output.

    Rules are matched on path suffixes; the rule's axes bind to the TRAILING
    dims, leading (layer-stacking) dims get None. Unmatched leaves (norms,
    biases, scalars) replicate.
    """
    def one(path, leaf):
        names = _path_names(path)
        for suffix, axes in _PARAM_RULES:
            if names[-len(suffix):] == suffix:
                pad = leaf.ndim - len(axes)
                if pad < 0:       # rank-reduced (e.g. unstacked) — replicate
                    return (None,) * leaf.ndim
                return (None,) * pad + tuple(axes)
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(one, params_shape)


# cache logical axes: shard batch dim + kv heads/ssm state over model axis.
# The cache TIME dim carries the "kv_time" logical name: by default it maps
# to no mesh axis, but architectures whose kv_heads don't divide the model
# axis (chatglm kv=2, kimi kv=8 on a 16-way axis) can route it to "model"
# via a rules override — otherwise their caches replicate model_size-fold.
def cache_logical_axes(cache_shape) -> Any:
    def one(path, leaf):
        names = _path_names(path)
        last = names[-1] if names else ""
        if last in ("k", "v"):        # (L, B, T, K, hd) or (B, T, K, hd)
            pad = leaf.ndim - 4
            return (None,) * pad + ("batch", "kv_time", "kv_heads", None)
        if last == "state":           # (.., B, H, P, N)
            pad = leaf.ndim - 4
            return (None,) * pad + ("batch", "ssm_inner", None, None)
        if last == "conv":            # (.., B, K-1, C)
            pad = leaf.ndim - 3
            return (None,) * pad + ("batch", None, "ssm_inner")
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(one, cache_shape)
