"""Positive/negative device pools with epsilon-greedy selection (Alg. 2 l.4-8).

Host-side bookkeeping (numpy RNG): pool membership is control-plane state,
not part of the jitted step. Semantics follow the paper exactly:

* both pools start with all devices in the positive pool;
* each round, with probability eps (default 0.8) the round's |S_t| = N*C
  devices are drawn from the positive pool, otherwise from the negative
  pool; if the chosen pool has too few members, the remainder is drawn from
  the other pool (Sec. 3.4);
* selected devices are removed from their pools for the round and re-filed
  according to the judgment verdict (positives -> positive pool, ...).

Shared label-distribution stats live here too: :func:`label_histograms`,
:func:`hist_entropy`, and :func:`greedy_entropy_groups` — the control-plane
inputs for FedCAT-style device concatenation (arXiv 2202.12751), where
devices are packed into ordered groups whose combined label distribution
is as close to uniform (maximum entropy) as a greedy pass can make it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .entropy import entropy_np


@dataclass
class DevicePools:
    num_devices: int
    eps: float = 0.8
    seed: int = 0
    positive: set[int] = field(init=False)
    negative: set[int] = field(init=False)
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self.positive = set(range(self.num_devices))
        self.negative = set()
        self._rng = np.random.default_rng(self.seed)

    # -- paper Alg.2 lines 4-8 -------------------------------------------
    def select(self, num: int) -> list[int]:
        """Draw the round's device set S_t (removed from the pools)."""
        num = min(num, self.num_devices)
        use_positive = self._rng.random() < self.eps
        first = self.positive if use_positive else self.negative
        second = self.negative if use_positive else self.positive

        take_first = min(num, len(first))
        chosen = list(self._rng.choice(sorted(first), take_first,
                                       replace=False)) if take_first else []
        remaining = num - take_first
        if remaining > 0:
            extra = list(self._rng.choice(sorted(second),
                                          min(remaining, len(second)),
                                          replace=False))
            chosen += extra
        chosen = [int(c) for c in chosen]
        for c in chosen:
            self.positive.discard(c)
            self.negative.discard(c)
        return chosen

    # -- paper Alg.2 line 22 ----------------------------------------------
    def update(self, positives: list[int], negatives: list[int]) -> None:
        self.positive.update(int(i) for i in positives)
        self.negative.update(int(i) for i in negatives)

    def stats(self) -> dict:
        return {"positive": len(self.positive), "negative": len(self.negative)}


# ---- traced pools (device-resident carry for the scan engine) ------------
#
# The paper's eps-greedy pool draw, as a pure jax function of
# (PRNG key, membership masks): the SAME jitted program backs both the
# host-side :class:`repro.fl.selectors.TracedPoolSelector` and the scan
# engine's in-``lax.scan`` pool carry, which is what makes an R-round
# folded block's selection stream bit-for-bit equal to the sequential
# ``Server`` driving the selector one round at a time. All scoring stays
# in int32/uint32 — the container runs without ``jax_enable_x64``, and a
# silent float64->float32 downcast in a sort key would fork the streams.

@partial(jax.jit, static_argnames=("num", "eps"))
def pools_draw(key: jax.Array, pos_mask: jax.Array, neg_mask: jax.Array,
               *, num: int, eps: float):
    """Alg. 2 lines 4-8 as a traced draw.

    With probability ``eps`` the round draws from the positive pool,
    otherwise the negative; if the chosen pool has fewer than ``num``
    members the remainder spills into the other pool (Sec. 3.4) — every
    device is always in exactly one pool between rounds, so the two pools
    jointly cover any ``num <= N``. Returns ``(sel, new_key)`` where
    ``sel`` is (num,) int32 client ids; the draw does NOT mutate the
    masks (removal + verdict re-filing fuse in :func:`pools_refile`).

    Mechanics: a uniform random uint31 per client fixes a random
    permutation (stable argsort of the negated bits), then a second
    stable argsort by first-pool membership floats the chosen pool's
    members to the front while preserving that permutation within each
    pool — i.e. "uniform without replacement from the first pool, then
    uniform from the spillover", exactly the host ``DevicePools``
    semantics (under a different RNG stream).
    """
    k_eps, k_bits, new_key = jax.random.split(key, 3)
    use_pos = jax.random.uniform(k_eps) < eps
    first = jnp.where(use_pos, pos_mask, neg_mask).astype(jnp.float32)
    n = pos_mask.shape[0]
    # uint32 >> 1 fits int32: the sort key stays exact without x64
    bits = (jax.random.bits(k_bits, (n,), jnp.uint32) >> jnp.uint32(1))
    perm = jnp.argsort(-bits.astype(jnp.int32), stable=True)
    front = jnp.argsort(-first[perm], stable=True)
    sel = perm[front][:num].astype(jnp.int32)
    return sel, new_key


@jax.jit
def pools_refile(pos_mask: jax.Array, neg_mask: jax.Array,
                 sel: jax.Array, admitted: jax.Array):
    """Alg. 2 line 22 fused with the draw's removal: the round's cohort
    leaves both pools and re-files by verdict (admitted -> positive),
    every other client's membership untouched. ``admitted`` is the (m,)
    0/1 verdict mask aligned with ``sel``."""
    n = pos_mask.shape[0]
    hot = jnp.zeros((n,), jnp.float32).at[sel].set(1.0)
    acc = jnp.zeros((n,), jnp.float32).at[sel].set(
        admitted.astype(jnp.float32))
    new_pos = jnp.where(hot > 0, acc, pos_mask.astype(jnp.float32))
    new_neg = jnp.where(hot > 0, 1.0 - acc, neg_mask.astype(jnp.float32))
    return new_pos, new_neg


# ---- label-distribution stats (FedCAT grouping inputs) -------------------

def label_histograms(y: np.ndarray, w: np.ndarray | None = None,
                     num_classes: int | None = None) -> np.ndarray:
    """Per-device weighted label counts: (N, S) labels -> (N, C) histograms.

    ``w`` is the per-sample weight mask ``stack_clients`` produces (padded
    samples carry weight 0, so they never count toward a distribution).
    """
    y = np.asarray(y)
    w = (np.ones(y.shape, np.float64) if w is None
         else np.asarray(w, np.float64))
    c = int(num_classes) if num_classes else int(y.max()) + 1
    hists = np.zeros((y.shape[0], c), np.float64)
    for i in range(y.shape[0]):
        hists[i] = np.bincount(y[i].reshape(-1),
                               weights=w[i].reshape(-1), minlength=c)[:c]
    return hists


def hist_entropy(hist: np.ndarray) -> float:
    """Shannon entropy (nats) of a count histogram; empty -> 0."""
    tot = float(np.sum(hist))
    if tot <= 0.0:
        return 0.0
    return float(entropy_np(np.asarray(hist, np.float64) / tot))


def greedy_entropy_groups(hists: np.ndarray,
                          group_size: int) -> list[list[int]]:
    """Partition rows into ordered groups of ``group_size``, greedily
    maximizing each group's combined label entropy (FedCAT grouping).

    Each group is seeded with the most label-skewed device left, then grown
    by the device whose addition raises the pooled histogram's entropy the
    most. Purely deterministic (ties break to the lowest index): the same
    histograms always produce the same groups, which is what lets chain
    dispatches be speculated and replayed bit-for-bit. The final group may
    be smaller when ``group_size`` does not divide the row count.
    """
    n = len(hists)
    k = max(1, int(group_size))
    remaining = list(range(n))
    groups: list[list[int]] = []
    while remaining:
        seed = min(remaining, key=lambda i: (hist_entropy(hists[i]), i))
        remaining.remove(seed)
        group = [seed]
        acc = np.array(hists[seed], np.float64)
        while len(group) < k and remaining:
            best = max(remaining,
                       key=lambda i: (hist_entropy(acc + hists[i]), -i))
            remaining.remove(best)
            group.append(best)
            acc += hists[best]
        groups.append(group)
    return groups
