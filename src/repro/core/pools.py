"""Positive/negative device pools with epsilon-greedy selection (Alg. 2 l.4-8).

Host-side bookkeeping (numpy RNG): pool membership is control-plane state,
not part of the jitted step. Semantics follow the paper exactly:

* both pools start with all devices in the positive pool;
* each round, with probability eps (default 0.8) the round's |S_t| = N*C
  devices are drawn from the positive pool, otherwise from the negative
  pool; if the chosen pool has too few members, the remainder is drawn from
  the other pool (Sec. 3.4);
* selected devices are removed from their pools for the round and re-filed
  according to the judgment verdict (positives -> positive pool, ...).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class DevicePools:
    num_devices: int
    eps: float = 0.8
    seed: int = 0
    positive: set[int] = field(init=False)
    negative: set[int] = field(init=False)
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self.positive = set(range(self.num_devices))
        self.negative = set()
        self._rng = np.random.default_rng(self.seed)

    # -- paper Alg.2 lines 4-8 -------------------------------------------
    def select(self, num: int) -> list[int]:
        """Draw the round's device set S_t (removed from the pools)."""
        num = min(num, self.num_devices)
        use_positive = self._rng.random() < self.eps
        first = self.positive if use_positive else self.negative
        second = self.negative if use_positive else self.positive

        take_first = min(num, len(first))
        chosen = list(self._rng.choice(sorted(first), take_first,
                                       replace=False)) if take_first else []
        remaining = num - take_first
        if remaining > 0:
            extra = list(self._rng.choice(sorted(second),
                                          min(remaining, len(second)),
                                          replace=False))
            chosen += extra
        chosen = [int(c) for c in chosen]
        for c in chosen:
            self.positive.discard(c)
            self.negative.discard(c)
        return chosen

    # -- paper Alg.2 line 22 ----------------------------------------------
    def update(self, positives: list[int], negatives: list[int]) -> None:
        self.positive.update(int(i) for i in positives)
        self.negative.update(int(i) for i in negatives)

    def stats(self) -> dict:
        return {"positive": len(self.positive), "negative": len(self.negative)}
