"""Positive/negative device pools with epsilon-greedy selection (Alg. 2 l.4-8).

Host-side bookkeeping (numpy RNG): pool membership is control-plane state,
not part of the jitted step. Semantics follow the paper exactly:

* both pools start with all devices in the positive pool;
* each round, with probability eps (default 0.8) the round's |S_t| = N*C
  devices are drawn from the positive pool, otherwise from the negative
  pool; if the chosen pool has too few members, the remainder is drawn from
  the other pool (Sec. 3.4);
* selected devices are removed from their pools for the round and re-filed
  according to the judgment verdict (positives -> positive pool, ...).

Shared label-distribution stats live here too: :func:`label_histograms`,
:func:`hist_entropy`, and :func:`greedy_entropy_groups` — the control-plane
inputs for FedCAT-style device concatenation (arXiv 2202.12751), where
devices are packed into ordered groups whose combined label distribution
is as close to uniform (maximum entropy) as a greedy pass can make it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .entropy import entropy_np


@dataclass
class DevicePools:
    num_devices: int
    eps: float = 0.8
    seed: int = 0
    positive: set[int] = field(init=False)
    negative: set[int] = field(init=False)
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self.positive = set(range(self.num_devices))
        self.negative = set()
        self._rng = np.random.default_rng(self.seed)

    # -- paper Alg.2 lines 4-8 -------------------------------------------
    def select(self, num: int) -> list[int]:
        """Draw the round's device set S_t (removed from the pools)."""
        num = min(num, self.num_devices)
        use_positive = self._rng.random() < self.eps
        first = self.positive if use_positive else self.negative
        second = self.negative if use_positive else self.positive

        take_first = min(num, len(first))
        chosen = list(self._rng.choice(sorted(first), take_first,
                                       replace=False)) if take_first else []
        remaining = num - take_first
        if remaining > 0:
            extra = list(self._rng.choice(sorted(second),
                                          min(remaining, len(second)),
                                          replace=False))
            chosen += extra
        chosen = [int(c) for c in chosen]
        for c in chosen:
            self.positive.discard(c)
            self.negative.discard(c)
        return chosen

    # -- paper Alg.2 line 22 ----------------------------------------------
    def update(self, positives: list[int], negatives: list[int]) -> None:
        self.positive.update(int(i) for i in positives)
        self.negative.update(int(i) for i in negatives)

    def stats(self) -> dict:
        return {"positive": len(self.positive), "negative": len(self.negative)}


# ---- label-distribution stats (FedCAT grouping inputs) -------------------

def label_histograms(y: np.ndarray, w: np.ndarray | None = None,
                     num_classes: int | None = None) -> np.ndarray:
    """Per-device weighted label counts: (N, S) labels -> (N, C) histograms.

    ``w`` is the per-sample weight mask ``stack_clients`` produces (padded
    samples carry weight 0, so they never count toward a distribution).
    """
    y = np.asarray(y)
    w = (np.ones(y.shape, np.float64) if w is None
         else np.asarray(w, np.float64))
    c = int(num_classes) if num_classes else int(y.max()) + 1
    hists = np.zeros((y.shape[0], c), np.float64)
    for i in range(y.shape[0]):
        hists[i] = np.bincount(y[i].reshape(-1),
                               weights=w[i].reshape(-1), minlength=c)[:c]
    return hists


def hist_entropy(hist: np.ndarray) -> float:
    """Shannon entropy (nats) of a count histogram; empty -> 0."""
    tot = float(np.sum(hist))
    if tot <= 0.0:
        return 0.0
    return float(entropy_np(np.asarray(hist, np.float64) / tot))


def greedy_entropy_groups(hists: np.ndarray,
                          group_size: int) -> list[list[int]]:
    """Partition rows into ordered groups of ``group_size``, greedily
    maximizing each group's combined label entropy (FedCAT grouping).

    Each group is seeded with the most label-skewed device left, then grown
    by the device whose addition raises the pooled histogram's entropy the
    most. Purely deterministic (ties break to the lowest index): the same
    histograms always produce the same groups, which is what lets chain
    dispatches be speculated and replayed bit-for-bit. The final group may
    be smaller when ``group_size`` does not divide the row count.
    """
    n = len(hists)
    k = max(1, int(group_size))
    remaining = list(range(n))
    groups: list[list[int]] = []
    while remaining:
        seed = min(remaining, key=lambda i: (hist_entropy(hists[i]), i))
        remaining.remove(seed)
        group = [seed]
        acc = np.array(hists[seed], np.float64)
        while len(group) < k and remaining:
            best = max(remaining,
                       key=lambda i: (hist_entropy(acc + hists[i]), -i))
            remaining.remove(best)
            group.append(best)
            acc += hists[best]
        groups.append(group)
    return groups
