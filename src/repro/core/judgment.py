"""Maximum Entropy Judgment (paper Algorithm 1).

Two interchangeable implementations:

* ``judge_np``      — literal numpy transcription of Algorithm 1 (the test
                      oracle; greedy per-iteration re-scan like the paper).
* ``judge``         — pure-JAX ``lax.while_loop`` version that runs *inside*
                      a jitted/pjitted train step. Uses the vectorized
                      leave-one-out sweep (O(M*C) per iteration) and returns
                      a float mask over the M candidates.

Both are exact greedy: per iteration, remove the single device whose removal
maximally increases the size-weighted group entropy; stop when no removal
strictly improves it. They provably agree (tests/test_judgment.py, incl. a
hypothesis sweep).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .entropy import (
    group_entropy,
    group_entropy_np,
    leave_one_out_entropies,
)

# Strict-improvement tolerance: float32 entropy of broad (e.g. 151k-class)
# distributions has ~1e-6 noise; require improvement above it.
_TOL = 1e-6


class JudgmentResult(NamedTuple):
    mask: jax.Array          # (M,) float32 — 1.0 = positive device (set A)
    entropy: jax.Array       # () final group entropy over positives
    initial_entropy: jax.Array  # () entropy before any removal
    num_removed: jax.Array   # () int32 — |R|
    # (M,) int32 device indices in greedy-removal order, -1 padded; None for
    # implementations that do not track order (judge_budgeted).
    removal_order: jax.Array | None = None


def judge(
    soft_labels: jax.Array,
    sizes: jax.Array,
    active: jax.Array | None = None,
    max_removals: int | None = None,
    backend: str = "xla",
    protected: jax.Array | None = None,
) -> JudgmentResult:
    """Algorithm 1 as a ``lax.while_loop`` — trace-compatible.

    soft_labels: (M, C) per-device mean softmax (Eq. 2).
    sizes:       (M,)   per-device sample counts (L in the paper).
    active:      (M,)   optional 0/1 mask of devices actually selected this
                        round (S_t); inactive devices are neither judged nor
                        returned as positive.
    max_removals: optional cap on |R| (defaults to M-1; the judgment can
                        never empty the set regardless).
    backend:     "xla" (pure jnp leave-one-out sweep) or "pallas" (the
                        entropy_judge kernel — class-axis-tiled, for huge C).
    protected:   (M,)   optional 0/1 mask of devices that contribute to the
                        group entropy but are never removal candidates —
                        the async engine's already-admitted buffer, whose
                        weights have already shipped.
    """
    soft_labels = jnp.asarray(soft_labels, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    m = soft_labels.shape[0]
    if active is None:
        active = jnp.ones((m,), jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    if protected is None:
        protected = jnp.zeros((m,), jnp.float32)
    protected = jnp.asarray(protected, jnp.float32)
    cap = m - 1 if max_removals is None else int(max_removals)

    init_ent = group_entropy(soft_labels, sizes, active)

    def cond(state):
        mask, ent, removed, improved, order = state
        return jnp.logical_and(improved, removed < cap)

    def _loo(mask):
        if backend == "pallas":
            from ..kernels import ops as kops
            _, loo = kops.entropy_judge_sweep(soft_labels, sizes, mask,
                                              backend="pallas")
            return loo
        return leave_one_out_entropies(soft_labels, sizes, mask)

    def body(state):
        mask, ent, removed, _, order = state
        loo = _loo(mask)                                         # (M,)
        # only currently-active, unprotected devices are candidates
        cand = jnp.where((mask > 0) & (protected == 0), loo, -jnp.inf)
        best = jnp.argmax(cand)
        best_ent = cand[best]
        improves = best_ent > ent + _TOL
        new_mask = jnp.where(
            improves, mask.at[best].set(0.0), mask
        )
        new_ent = jnp.where(improves, best_ent, ent)
        new_order = jnp.where(
            improves, order.at[removed].set(best.astype(jnp.int32)), order)
        return (new_mask, new_ent,
                removed + jnp.where(improves, 1, 0).astype(jnp.int32),
                improves, new_order)

    mask, ent, removed, _, order = jax.lax.while_loop(
        cond, body,
        (active, init_ent, jnp.zeros((), jnp.int32), jnp.array(True),
         jnp.full((m,), -1, jnp.int32)),
    )
    return JudgmentResult(mask=mask, entropy=ent,
                          initial_entropy=init_ent, num_removed=removed,
                          removal_order=order)


def judge_budgeted(
    soft_labels: jax.Array,
    sizes: jax.Array,
    budget: int,
    active: jax.Array | None = None,
) -> JudgmentResult:
    """Beyond-paper variant: FORWARD greedy selection under a fixed uplink
    budget — pick exactly ``budget`` devices that maximize the group
    entropy, growing the set from empty (facility-location-style greedy).

    The paper's Algorithm 1 removes harmful devices but the number of
    uploads per round is whatever survives; cross-device deployments often
    need a hard per-round upload budget instead. Greedy forward selection
    gives that knob while keeping the same maximum-entropy objective.
    """
    soft_labels = jnp.asarray(soft_labels, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    m = soft_labels.shape[0]
    if active is None:
        active = jnp.ones((m,), jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    budget = min(int(budget), m)
    init_ent = group_entropy(soft_labels, sizes, active)

    def add_one(state, _):
        mask = state
        w = sizes * mask
        tot = jnp.sum(w)
        s = jnp.einsum("m,mc->c", w, soft_labels)
        # entropy if device k were ADDED
        num = s[None, :] + (sizes * active)[:, None] * soft_labels
        den = (tot + sizes * active)[:, None]
        ent_add = -jnp.sum(jnp.where(num > 0, (num / den) *
                                     jnp.log(jnp.clip(num / den, 1e-12,
                                                      None)), 0.0), axis=-1)
        cand = jnp.where((mask == 0) & (active > 0), ent_add, -jnp.inf)
        best = jnp.argmax(cand)
        return mask.at[best].set(1.0), None

    mask, _ = jax.lax.scan(add_one, jnp.zeros((m,), jnp.float32), None,
                           length=budget)
    ent = group_entropy(soft_labels, sizes, mask)
    removed = (jnp.sum(active) - jnp.sum(mask)).astype(jnp.int32)
    return JudgmentResult(mask=mask, entropy=ent,
                          initial_entropy=init_ent, num_removed=removed)


def judge_np(
    soft_labels: np.ndarray,
    sizes: np.ndarray,
    active: np.ndarray | None = None,
    protected: np.ndarray | None = None,
) -> tuple[list[int], list[int], float]:
    """Literal Algorithm 1. Returns (A, R, final_entropy) with device indices.

    Per paper lines 2-19: iteratively find the single member whose removal
    maximises getEntropy of the remainder; move it from A to R; stop when no
    removal strictly improves the entropy (line 13-14). ``protected`` rows
    (the async engine's already-shipped admission buffer) stay in A and in
    the entropy, but the sweep never removes them.
    """
    soft_labels = np.asarray(soft_labels, np.float64)
    sizes = np.asarray(sizes, np.float64)
    m = soft_labels.shape[0]
    if active is None:
        active_idx = list(range(m))
    else:
        active_idx = [i for i in range(m) if active[i] > 0]

    A = list(active_idx)
    R: list[int] = []
    mask = np.zeros(m)
    mask[A] = 1.0
    ent = group_entropy_np(soft_labels, sizes, mask)
    while len(A) > 1:
        best_k, best_ent = None, ent
        for k in A:  # paper line 5: sweep candidates
            if protected is not None and protected[k] > 0:
                continue
            trial = mask.copy()
            trial[k] = 0.0
            e = group_entropy_np(soft_labels, sizes, trial)
            if e > best_ent + _TOL:
                best_k, best_ent = k, e
        if best_k is None:  # line 13: no harmful device left
            break
        A.remove(best_k)
        R.append(best_k)
        mask[best_k] = 0.0
        ent = best_ent
    return A, R, ent
