"""Local client-update rules (``ClientUpdate`` in paper Alg. 2 line 11).

FedEntropy is optimizer-agnostic (paper Sec. 3.4 / Table 3): the judgment
wraps any of these local strategies. Implemented, matching the paper's
baselines:

* ``fedavg``   — E epochs of minibatch SGD(+momentum) on CE loss.
* ``fedprox``  — + (mu/2)||w - w_global||^2 proximal term  [Li et al. 2020].
* ``scaffold`` — control-variate-corrected SGD; client variate update
                 "option II": c_i+ = c_i - c + (w_g - w_i)/(K*eta)
                 [Karimireddy et al. 2020]. Doubles uplink payload.
* ``moon``     — model-contrastive term between current, global and previous
                 local representations [Li et al. 2021].

All are pure-JAX and vmappable over a leading client axis; per-sample
``weight`` masks make padded client datasets exact.

The model is abstracted as ``apply(params, x) -> (logits, features)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any
ApplyFn = Callable[[Params, jax.Array], tuple[jax.Array, jax.Array]]


@dataclass(frozen=True)
class LocalSpec:
    strategy: str = "fedavg"          # fedavg | fedprox | scaffold | moon
    lr: float = 0.01                  # paper Sec. 4.1
    momentum: float = 0.5             # paper Sec. 4.1
    epochs: int = 5                   # paper E = 5
    batch_size: int = 50              # paper Sec. 4.1
    prox_mu: float = 0.01             # paper's FedProx mu
    moon_mu: float = 0.1              # paper's Moon mu
    moon_tau: float = 0.5             # paper's Moon temperature
    scaffold_lr_g: float = 1.0        # paper's SCAFFOLD global step size


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  weights: jax.Array | None = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * weights) / jnp.clip(jnp.sum(weights), 1e-12, None)


def _sqnorm_diff(a, b):
    return sum(jnp.sum((x - y.astype(x.dtype)) ** 2)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _moon_term(z, z_glob, z_prev, tau):
    """-log( e^{sim(z,zg)/tau} / (e^{sim(z,zg)/tau} + e^{sim(z,zp)/tau}) )."""
    def cos(a, b):
        a = a / jnp.clip(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-9)
        b = b / jnp.clip(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-9)
        return jnp.sum(a * b, axis=-1)
    pos = cos(z, z_glob) / tau
    neg = cos(z, z_prev) / tau
    return jnp.mean(jnp.logaddexp(pos, neg) - pos)


def client_update(
    apply_fn: ApplyFn,
    global_params: Params,
    data: dict,                     # x:(S,...), y:(S,), w:(S,) sample mask
    spec: LocalSpec,
    *,
    prev_params: Params | None = None,      # moon
    c_local: Params | None = None,          # scaffold c_i
    c_global: Params | None = None,         # scaffold c
    rng: jax.Array | None = None,
) -> dict:
    """Run E local epochs; return new params (+ strategy state + soft label).

    The dataset is consumed in fixed minibatches via a batched scan; sample
    weights keep padded entries exact (they contribute zero loss/softlabel).
    """
    x, y, w = data["x"], data["y"], data["w"]
    s = x.shape[0]
    bs = min(spec.batch_size, s)
    nb = s // bs
    xb = x[: nb * bs].reshape((nb, bs) + x.shape[1:])
    yb = y[: nb * bs].reshape((nb, bs))
    wb = w[: nb * bs].reshape((nb, bs))

    def loss_fn(p, bx, by, bw):
        logits, feats = apply_fn(p, bx)
        loss = cross_entropy(logits, by, bw)
        if spec.strategy == "fedprox":
            loss = loss + 0.5 * spec.prox_mu * _sqnorm_diff(p, global_params)
        elif spec.strategy == "moon" and prev_params is not None:
            _, zg = apply_fn(global_params, bx)
            _, zp = apply_fn(prev_params, bx)
            loss = loss + spec.moon_mu * _moon_term(feats, zg, zp,
                                                    spec.moon_tau)
        return loss

    grad_fn = jax.grad(loss_fn)

    def sgd_step(carry, batch):
        p, mom = carry
        bx, by, bw = batch
        g = grad_fn(p, bx, by, bw)
        if spec.strategy == "scaffold" and c_local is not None:
            g = jax.tree.map(lambda gi, ci, cg: gi - ci + cg,
                             g, c_local, c_global)
        mom = jax.tree.map(lambda m, gi: spec.momentum * m + gi, mom, g)
        p = jax.tree.map(lambda pi, m: pi - spec.lr * m, p, mom)
        return (p, mom), None

    params = global_params
    mom0 = jax.tree.map(jnp.zeros_like, params)

    def epoch(carry, _):
        carry, _ = jax.lax.scan(sgd_step, carry, (xb, yb, wb))
        return carry, None

    (params, _), _ = jax.lax.scan(epoch, (params, mom0), None,
                                  length=spec.epochs)

    # ---- soft label (paper Eq. 2) over the WHOLE local dataset ------------
    logits, _ = apply_fn(params, x)
    probs = jax.nn.softmax(logits, axis=-1)
    size = jnp.clip(jnp.sum(w), 1e-12, None)
    soft = jnp.einsum("s,sc->c", w, probs) / size

    out = {"params": params, "soft_label": soft, "size": jnp.sum(w)}

    if spec.strategy == "scaffold" and c_local is not None:
        k = nb * spec.epochs
        new_c = jax.tree.map(
            lambda ci, cg, wg, wi: ci - cg + (wg - wi) / (k * spec.lr),
            c_local, c_global, global_params, params)
        out["c_local"] = new_c
        out["c_delta"] = jax.tree.map(lambda a, b: a - b, new_c, c_local)
    return out
