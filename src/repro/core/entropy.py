"""Soft-label statistics and entropy (paper Eq. 2-4).

A *soft label* for device k is the average softmax output over its local
samples (Eq. 2):  p_k = (1/l_k) sum_i softmax(model_k(x_k^i)).

The judgment operates on the dataset-size-weighted mean of the soft labels
of the currently-active device set (Eq. 4) and its Shannon entropy (Eq. 3).

Everything here is pure jnp (differentiable where meaningful) and has a
matching numpy oracle used by the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


def entropy(p: jax.Array, axis: int = -1) -> jax.Array:
    """Shannon entropy H(p) = -sum_i p_i log p_i  (paper Eq. 3), nats.

    Zero probabilities contribute zero (lim p->0 of p log p).
    """
    p = jnp.asarray(p)
    plogp = jnp.where(p > 0, p * jnp.log(jnp.clip(p, _EPS, None)), 0.0)
    return -jnp.sum(plogp, axis=axis)


def entropy_np(p: np.ndarray, axis: int = -1) -> np.ndarray:
    p = np.asarray(p, dtype=np.float64)
    plogp = np.where(p > 0, p * np.log(np.clip(p, _EPS, None)), 0.0)
    return -np.sum(plogp, axis=axis)


def soft_label(logits: jax.Array) -> jax.Array:
    """Device soft label from per-sample logits (paper Eq. 2).

    logits: (num_samples, num_classes) -> (num_classes,) mean softmax.
    """
    return jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)


def masked_soft_label_mean(
    soft_labels: jax.Array, sizes: jax.Array, mask: jax.Array
) -> jax.Array:
    """Size-weighted mean soft label over the active device set (Eq. 4 inner).

    soft_labels: (M, C); sizes: (M,); mask: (M,) float/bool.
    Returns (C,) distribution. If the mask is empty, returns uniform (max
    entropy) so an empty set is never preferred by the greedy judgment.
    """
    w = sizes * mask
    tot = jnp.sum(w)
    mean = jnp.einsum("m,mc->c", w, soft_labels) / jnp.clip(tot, _EPS, None)
    uniform = jnp.full(soft_labels.shape[-1], 1.0 / soft_labels.shape[-1],
                       dtype=mean.dtype)
    return jnp.where(tot > 0, mean, uniform)


def group_entropy(
    soft_labels: jax.Array, sizes: jax.Array, mask: jax.Array
) -> jax.Array:
    """getEntropy(P, L) of paper Eq. 4 for the active set given by ``mask``."""
    return entropy(masked_soft_label_mean(soft_labels, sizes, mask))


def leave_one_out_entropies(
    soft_labels: jax.Array, sizes: jax.Array, mask: jax.Array
) -> jax.Array:
    """Entropy of the active set with device k removed, for every k. (M,).

    Vectorized form of the paper's Alg. 1 lines 5-12 inner sweep: computed
    from the full weighted sum by subtracting each member's contribution,
    so the sweep is O(M*C) instead of O(M^2*C).

    For k not in the active set the value is the current group entropy
    (removing an absent device changes nothing — w_k = 0 recovers the full
    mean). A removal that would EMPTY the active set returns -1.0 (entropy
    is always >= 0) so the greedy judgment can never empty the set.
    """
    w = sizes * mask                       # (M,)
    tot = jnp.sum(w)
    s = jnp.einsum("m,mc->c", w, soft_labels)          # (C,)
    # leave-one-out weighted mean for every k: (s - w_k p_k) / (tot - w_k)
    num = s[None, :] - w[:, None] * soft_labels        # (M, C)
    den = jnp.clip(tot - w, _EPS, None)[:, None]
    loo = num / den
    ent = entropy(loo, axis=-1)
    return jnp.where(tot - w > _EPS, ent, -1.0)


# ---------------------------------------------------------------- numpy refs

def soft_label_np(logits: np.ndarray) -> np.ndarray:
    z = logits - logits.max(axis=-1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=-1, keepdims=True)
    return p.mean(axis=0)


def group_entropy_np(
    soft_labels: np.ndarray, sizes: np.ndarray, mask: np.ndarray
) -> float:
    w = np.asarray(sizes, np.float64) * np.asarray(mask, np.float64)
    tot = w.sum()
    if tot <= 0:
        c = soft_labels.shape[-1]
        return float(np.log(c))
    mean = (w[:, None] * soft_labels).sum(axis=0) / tot
    return float(entropy_np(mean))
