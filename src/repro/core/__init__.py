from . import aggregation, entropy, judgment, pools, simulator, strategies
