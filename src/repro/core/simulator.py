"""Legacy FedEntropy trainer — now a thin shim over :mod:`repro.fl`.

The monolithic simulator was decomposed into the pluggable
Selector/ClientStrategy/Judge/Aggregator server API (see
``repro.fl``'s module docstring for the migration table).
``FedEntropyTrainer`` remains for existing callers and reproduces the
seed trainer's round histories bit-for-bit on fixed seeds
(tests/test_fl_api.py checks it against recorded golden histories): the
ablation booleans map onto component choices —

* ``use_judgment=False`` -> ``PassThroughJudge`` (FedAvg-of-selected),
* ``use_pools=False``    -> ``UniformSelector`` seeded ``seed + 1``
  (the legacy uniform RNG stream).

New code should compose ``repro.fl.build(...)`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from ..fl import registry as _registry
from ..fl.aggregators import ScaffoldAggregator, WeightedAverageAggregator
from ..fl.judges import MaxEntropyJudge, PassThroughJudge
from ..fl.selectors import PoolSelector, UniformSelector
from ..fl.server import Server, ServerConfig, total_uplink_bytes
from .pools import DevicePools
from .strategies import ApplyFn, LocalSpec

__all__ = ["FLConfig", "FedEntropyTrainer", "total_uplink_bytes"]


@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100          # paper N
    participation: float = 0.1      # paper C
    rounds: int = 1000              # paper T
    eps: float = 0.8                # paper epsilon
    use_judgment: bool = True       # False -> FedAvg-of-selected (ablation)
    use_pools: bool = True          # False -> uniform selection (ablation)
    seed: int = 0


class FedEntropyTrainer:
    """Back-compat facade: one ``round()`` = paper Alg. 2 lines 4-22."""

    def __init__(
        self,
        apply_fn: ApplyFn,
        init_params,
        client_data: dict,          # x:(N,S,...), y:(N,S), w:(N,S)
        fl: FLConfig,
        local: LocalSpec,
    ):
        self.fl = fl
        self.local = local
        cfg = ServerConfig(num_clients=fl.num_clients,
                           participation=fl.participation,
                           eps=fl.eps, seed=fl.seed)
        if fl.use_pools:
            selector = PoolSelector(fl.num_clients, fl.eps, fl.seed)
            self.pools = selector.pools
            self._shadow_pools = None
        else:
            selector = UniformSelector(fl.num_clients, fl.seed + 1)
            # the legacy trainer kept (and verdict-updated) pools even in
            # the uniform ablation; mirror that for observability.
            self.pools = DevicePools(fl.num_clients, fl.eps, fl.seed)
            self._shadow_pools = self.pools
        strategy = _registry.get("strategy", local.strategy)(local)
        aggregator = (ScaffoldAggregator(local.scaffold_lr_g)
                      if local.strategy == "scaffold"
                      else WeightedAverageAggregator())
        judge = MaxEntropyJudge() if fl.use_judgment else PassThroughJudge()
        self._server = Server(apply_fn, init_params, client_data, cfg,
                              selector=selector, strategy=strategy,
                              judge=judge, aggregator=aggregator)

    # ---- delegated state --------------------------------------------------
    @property
    def apply_fn(self) -> ApplyFn:
        return self._server.apply_fn

    @property
    def data(self) -> dict:
        return self._server.data

    @property
    def global_params(self):
        return self._server.global_params

    @global_params.setter
    def global_params(self, value):
        self._server.global_params = value

    @property
    def history(self) -> list[dict]:
        return self._server.history

    @property
    def round_idx(self) -> int:
        return self._server.round_idx

    @property
    def c_global(self):                     # legacy scaffold attribute
        return self._server.state["c_global"]

    @property
    def c_local(self):                      # legacy scaffold attribute
        return self._server.state["c_local"]

    @property
    def prev_params(self):                  # legacy moon attribute
        return self._server.state["prev_params"]

    # ---- delegated behaviour ---------------------------------------------
    def round(self) -> dict:
        rec = self._server.round()
        if self._shadow_pools is not None:
            self._shadow_pools.update(rec["positive"], rec["negative"])
        return rec

    def evaluate(self, x: jax.Array, y: jax.Array,
                 batch: int = 512) -> dict:
        return self._server.evaluate(x, y, batch=batch)

    def run(self, rounds: int, eval_every: int = 0, eval_data=None) -> list:
        evals = []
        for r in range(rounds):
            self.round()
            if eval_every and eval_data is not None and \
                    (r + 1) % eval_every == 0:
                m = self.evaluate(*eval_data)
                m["round"] = self.round_idx
                evals.append(m)
        return evals
