"""Faithful FedEntropy simulator (paper Algorithm 2), vmapped over clients.

The paper trains 100 PyTorch clients sequentially on one GPU; the JAX-native
equivalent stacks the selected clients' params/data on a leading axis and
runs ``ClientUpdate`` once under ``jax.vmap`` — identical math, one XLA
program. Pool bookkeeping (eps-greedy, Alg. 2 lines 4-8/22) stays host-side.

Supports the paper's four local strategies and the two ablations of Fig. 3b
(``use_judgment=False`` -> plain FedAvg-style aggregation of all selected;
``use_pools=False`` -> uniform random selection, judgment still applied).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .aggregation import aggregate, comm_bytes, tree_bytes
from .judgment import judge_np
from .pools import DevicePools
from .strategies import ApplyFn, LocalSpec, client_update, cross_entropy


@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100          # paper N
    participation: float = 0.1      # paper C
    rounds: int = 1000              # paper T
    eps: float = 0.8                # paper epsilon
    use_judgment: bool = True       # False -> FedAvg-of-selected (ablation)
    use_pools: bool = True          # False -> uniform selection (ablation)
    seed: int = 0


_VMAPPED_CACHE: dict = {}
_EVAL_CACHE: dict = {}


class FedEntropyTrainer:
    """Host-side FL loop; one ``round()`` = paper Alg. 2 lines 4-22."""

    def __init__(
        self,
        apply_fn: ApplyFn,
        init_params,
        client_data: dict,          # x:(N,S,...), y:(N,S), w:(N,S)
        fl: FLConfig,
        local: LocalSpec,
    ):
        self.apply_fn = apply_fn
        self.global_params = init_params
        self.data = client_data
        self.fl = fl
        self.local = local
        self.pools = DevicePools(fl.num_clients, fl.eps, fl.seed)
        self._uniform_rng = np.random.default_rng(fl.seed + 1)
        self.round_idx = 0
        self.history: list[dict] = []

        if local.strategy == "scaffold":
            z = jax.tree.map(jnp.zeros_like, init_params)
            self.c_global = z
            self.c_local = jax.tree.map(
                lambda x: jnp.zeros((fl.num_clients,) + x.shape, x.dtype),
                init_params)
        if local.strategy == "moon":
            self.prev_params = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (fl.num_clients,) + x.shape),
                init_params)

        # jit cache shared across trainer instances: benchmarks build many
        # trainers with identical (strategy, shapes) — recompiling each
        # would dominate CPU wall time.
        key = (local, apply_fn,
               tuple((k, v.shape, str(v.dtype))
                     for k, v in sorted(client_data.items())))
        if key not in _VMAPPED_CACHE:
            _VMAPPED_CACHE[key] = jax.jit(self._make_vmapped())
        self._vmapped = _VMAPPED_CACHE[key]

    # ------------------------------------------------------------------
    def _make_vmapped(self):
        spec, apply_fn = self.local, self.apply_fn

        def one(global_params, data, prev_p, c_loc, c_glob):
            return client_update(
                apply_fn, global_params, data, spec,
                prev_params=prev_p, c_local=c_loc, c_global=c_glob)

        in_axes = (None, 0,
                   0 if spec.strategy == "moon" else None,
                   0 if spec.strategy == "scaffold" else None,
                   None)
        return jax.vmap(one, in_axes=in_axes)

    # ------------------------------------------------------------------
    def _select(self) -> list[int]:
        k = max(1, int(round(self.fl.num_clients * self.fl.participation)))
        if self.fl.use_pools:
            return self.pools.select(k)
        return [int(i) for i in self._uniform_rng.choice(
            self.fl.num_clients, k, replace=False)]

    def round(self) -> dict:
        sel = self._select()
        idx = np.asarray(sel)
        data = {k: v[idx] for k, v in self.data.items()}

        prev_p = (jax.tree.map(lambda x: x[idx], self.prev_params)
                  if self.local.strategy == "moon" else None)
        c_loc = (jax.tree.map(lambda x: x[idx], self.c_local)
                 if self.local.strategy == "scaffold" else None)
        c_glob = getattr(self, "c_global", None)

        out = self._vmapped(self.global_params, data, prev_p, c_loc, c_glob)

        soft = np.asarray(out["soft_label"], np.float64)   # (|S_t|, C)
        sizes = np.asarray(out["size"], np.float64)

        if self.fl.use_judgment:
            a_rel, r_rel, ent = judge_np(soft, sizes)
        else:
            a_rel, r_rel = list(range(len(sel))), []
            ent = float("nan")
        mask = np.zeros(len(sel), np.float32)
        mask[a_rel] = 1.0

        # ---- aggregation (Alg. 2 line 21) -----------------------------
        new_global = aggregate(out["params"], jnp.asarray(sizes, jnp.float32),
                               jnp.asarray(mask))
        if self.local.strategy == "scaffold":
            # w_g <- w_g + eta_g * (agg - w_g); c <- c + |S_t|/N * mean dc
            eta = self.local.scaffold_lr_g
            new_global = jax.tree.map(
                lambda wg, ag: wg + eta * (ag.astype(wg.dtype) - wg),
                self.global_params, new_global)
            frac = len(sel) / self.fl.num_clients
            dc = jax.tree.map(lambda d: jnp.mean(d, axis=0), out["c_delta"])
            self.c_global = jax.tree.map(
                lambda c, d: c + frac * d, self.c_global, dc)
            self.c_local = jax.tree.map(
                lambda full, new: full.at[idx].set(new),
                self.c_local, out["c_local"])
        self.global_params = new_global

        if self.local.strategy == "moon":
            self.prev_params = jax.tree.map(
                lambda full, new: full.at[idx].set(new),
                self.prev_params, out["params"])

        # ---- pools update (Alg. 2 line 22) -----------------------------
        pos = [sel[i] for i in a_rel]
        neg = [sel[i] for i in r_rel]
        self.pools.update(pos, neg)

        comm = comm_bytes(self.global_params, len(sel), len(pos),
                          soft.shape[-1],
                          control_variate=self.local.strategy == "scaffold")
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": ent, "comm": comm}
        self.history.append(rec)
        self.round_idx += 1
        return rec

    # ------------------------------------------------------------------
    def evaluate(self, x: jax.Array, y: jax.Array,
                 batch: int = 512) -> dict:
        n = x.shape[0]
        correct, loss_sum = 0.0, 0.0
        if self.apply_fn not in _EVAL_CACHE:
            fn = self.apply_fn
            _EVAL_CACHE[fn] = jax.jit(lambda p, bx: fn(p, bx)[0])
        f = _EVAL_CACHE[self.apply_fn]
        for i in range(0, n, batch):
            bx, by = x[i:i + batch], y[i:i + batch]
            logits = f(self.global_params, bx)
            correct += float(jnp.sum(jnp.argmax(logits, -1) == by))
            loss_sum += float(cross_entropy(logits, by)) * bx.shape[0]
        return {"accuracy": correct / n, "loss": loss_sum / n}

    def run(self, rounds: int, eval_every: int = 0, eval_data=None) -> list:
        evals = []
        for r in range(rounds):
            self.round()
            if eval_every and eval_data is not None and \
                    (r + 1) % eval_every == 0:
                m = self.evaluate(*eval_data)
                m["round"] = self.round_idx
                evals.append(m)
        return evals


def total_uplink_bytes(history: list[dict]) -> int:
    return int(sum(h["comm"]["total_bytes"] for h in history))
