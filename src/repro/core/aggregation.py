"""Model aggregation (paper Alg. 2 line 21) over pytrees.

``aggregate``         — size-weighted FedAvg of stacked client params,
                        restricted to the positive mask (w_g = sum_i L_i w_i
                        / sum_i L_i over i in A).
``masked_mean_tree``  — generic masked weighted mean over a leading client
                        axis of every leaf.
``comm_bytes``        — accounting helper: uplink bytes actually transferred
                        for a round (positives upload models; every selected
                        device uploads its soft label first — stage 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def masked_mean_tree(stacked_tree, sizes: jax.Array, mask: jax.Array):
    """Weighted mean over leading axis M of every leaf, weights sizes*mask."""
    w = (jnp.asarray(sizes, jnp.float32) * jnp.asarray(mask, jnp.float32))
    tot = jnp.clip(jnp.sum(w), _EPS, None)

    def leaf(x):
        wl = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wl, axis=0) / tot.astype(x.dtype)

    return jax.tree.map(leaf, stacked_tree)


def aggregate(stacked_params, sizes: jax.Array, mask: jax.Array):
    """Paper Alg. 2 line 21: w_g = sum_{i in A} L_i * W_i / sum_{i in A} L_i."""
    return masked_mean_tree(stacked_params, sizes, mask)


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def comm_bytes(
    model_template,
    num_selected: int,
    num_positive: int,
    num_classes: int,
    soft_label_bytes_per_class: int = 4,
    control_variate: bool = False,
) -> dict:
    """Uplink communication accounting for one round.

    Stage 1: every selected device uploads a soft label (C floats).
    Stage 2: only positive devices upload models (paper's saving).
    SCAFFOLD-style optimizers double the model payload (control variates).
    """
    model_b = tree_bytes(model_template) * (2 if control_variate else 1)
    soft = num_selected * num_classes * soft_label_bytes_per_class
    models = num_positive * model_b
    return {
        "soft_label_bytes": soft,
        "model_bytes": models,
        "total_bytes": soft + models,
        "fedavg_equivalent_bytes": num_selected * model_b,
        "savings_fraction": 1.0 - (soft + models) / max(
            num_selected * model_b, 1),
    }
