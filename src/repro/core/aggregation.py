"""Model aggregation (paper Alg. 2 line 21) over pytrees.

``aggregate``         — size-weighted FedAvg of stacked client params,
                        restricted to the positive mask (w_g = sum_i L_i w_i
                        / sum_i L_i over i in A).
``masked_mean_tree``  — generic masked weighted mean over a leading client
                        axis of every leaf.
``fused_aggregate``   — the same reduction as one flat segment-reduce:
                        every leaf reshaped into a single (M, P) buffer and
                        summed in one kernel launch (Pallas or xla) instead
                        of a per-leaf tree_map — the launch-count win for
                        LM-sized pytrees with hundreds of leaves.
``comm_bytes``        — accounting helper: uplink bytes actually transferred
                        for a round (positives upload models; every selected
                        device uploads its soft label first — stage 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


def masked_mean_tree(stacked_tree, sizes: jax.Array, mask: jax.Array):
    """Weighted mean over leading axis M of every leaf, weights sizes*mask.

    Low-precision leaves (bf16/f16) accumulate in float32 — summing a
    large cohort in the leaf dtype loses mass (bf16 has 8 mantissa bits)
    — and cast back on return. Float32 leaves run the identical ops as
    before, so fixed-seed histories are unchanged bit-for-bit.
    """
    w = (jnp.asarray(sizes, jnp.float32) * jnp.asarray(mask, jnp.float32))
    tot = jnp.clip(jnp.sum(w), _EPS, None)

    def leaf(x):
        acc = jnp.promote_types(x.dtype, jnp.float32)
        wl = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(acc)
        out = jnp.sum(x.astype(acc) * wl, axis=0) / tot.astype(acc)
        return out.astype(x.dtype)

    return jax.tree.map(leaf, stacked_tree)


def fused_aggregate(stacked_tree, sizes: jax.Array, mask: jax.Array,
                    *, backend: str | None = None, block_p: int = 2048,
                    vmem_budget_bytes: int = 4 * 1024 * 1024):
    """:func:`masked_mean_tree` as ONE flat reduction.

    Flattens every leaf of the stacked client pytree into a single
    ``(M, P)`` float32 buffer (P = total param count) and runs one
    weighted segment-reduce over the client axis
    (:func:`repro.kernels.ops.masked_weighted_sum`; ``backend="pallas"``
    tiles both the client and param axes through a
    ``vmem_budget_bytes``-bounded grid — LM-sized P never pins an
    (M, P) stripe in VMEM — ``"xla"``/None is the fused-jnp reference),
    then unflattens back to the leaf shapes/dtypes. The pre-flatten f32
    cast means low-precision (bf16) leaves accumulate in f32, the same
    accumulate-dtype contract as ``masked_mean_tree``. Matches
    ``masked_mean_tree`` to float32 tolerance — the reduction order over
    the flat buffer differs from the per-leaf order, so this is a
    tolerance contract, not a bitwise one.
    """
    from ..kernels import ops as kops

    leaves, treedef = jax.tree.flatten(stacked_tree)
    m = leaves[0].shape[0]
    w = (jnp.asarray(sizes, jnp.float32) * jnp.asarray(mask, jnp.float32))
    tot = jnp.clip(jnp.sum(w), _EPS, None)
    flat = jnp.concatenate(
        [x.reshape(m, -1).astype(jnp.float32) for x in leaves], axis=1)
    red = kops.masked_weighted_sum(
        flat, w, backend=backend, block_p=block_p,
        vmem_budget_bytes=vmem_budget_bytes) / tot
    outs, off = [], 0
    for x in leaves:
        n = int(np.prod(x.shape[1:], dtype=np.int64))
        outs.append(red[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, outs)


def aggregate(stacked_params, sizes: jax.Array, mask: jax.Array):
    """Paper Alg. 2 line 21: w_g = sum_{i in A} L_i * W_i / sum_{i in A} L_i."""
    return masked_mean_tree(stacked_params, sizes, mask)


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def comm_bytes(
    model_template,
    num_selected: int,
    num_positive: int,
    num_classes: int,
    soft_label_bytes_per_class: int = 4,
    control_variate: bool = False,
) -> dict:
    """Uplink communication accounting for one round.

    Stage 1: every selected device uploads a soft label (C floats).
    Stage 2: only positive devices upload models (paper's saving).
    SCAFFOLD-style optimizers double the model payload (control variates).
    """
    model_b = tree_bytes(model_template) * (2 if control_variate else 1)
    soft = num_selected * num_classes * soft_label_bytes_per_class
    models = num_positive * model_b
    return {
        "soft_label_bytes": soft,
        "model_bytes": models,
        "total_bytes": soft + models,
        "fedavg_equivalent_bytes": num_selected * model_b,
        "savings_fraction": 1.0 - (soft + models) / max(
            num_selected * model_b, 1),
    }
