"""Selector implementations: who is asked to train this round.

``PoolSelector``    — the paper's epsilon-greedy positive/negative pools
                      (Alg. 2 lines 4-8/22), delegating to
                      ``core.pools.DevicePools``.
``UniformSelector`` — uniform sampling without replacement (the
                      ``use_pools=False`` ablation of Fig. 3b). Seeded with
                      ``seed + 1`` by the registry to match the legacy
                      trainer's RNG stream exactly.
``TracedPoolSelector`` — the same eps-greedy pool semantics driven by a
                      ``jax.random`` stream (``core.pools.pools_draw`` /
                      ``pools_refile``), so the draw can ALSO run inside
                      the scan engine's ``lax.scan`` as a device-resident
                      carry: ``engine="scan"`` folds R>1 rounds of the
                      paper's fedentropy composition instead of falling
                      back to sequential rounds. Not RNG-stream-compatible
                      with the numpy ``PoolSelector`` (histories are
                      reproducible per seed, not golden-comparable).
``CatGrouper``      — FedCAT (arXiv 2202.12751) device grouping layered
                      over an inner selector: WHO trains is delegated, and
                      the selection is additionally packed into ordered
                      groups via ``core.pools.greedy_entropy_groups``;
                      ``catgroups`` wraps ``uniform`` (plain fedcat),
                      ``catgroups-pools`` wraps ``pools`` (fedcat+maxent).
``QueueSelector``   — entropy-driven participant selection with dynamic
                      data queues (arXiv 2410.17792): clients are ranked
                      by label-distribution entropy off the bound corpus
                      stats, eps-greedy explored, and each round releases
                      a growing prefix of every selected client's local
                      dataset via a ``DataQueue`` schedule that the server
                      applies inside the cohort gather.

Selectors that consume corpus statistics implement ``bind_data`` — the
server passes its data plane (device-resident
:class:`repro.data.corpus.ClientCorpus` or streaming
:class:`repro.data.stream.HostCorpus`; the stats surface is duck-typed,
so either plane binds transparently), whose cached
``label_histograms()``/``sizes()`` replace the per-selector recompute
(a raw stacked dict still binds, for direct construction in tests).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core.pools import (
    DevicePools, greedy_entropy_groups, hist_entropy, label_histograms,
    pools_draw,
)
from ..data.corpus import DataQueue
from .registry import register


def _corpus_histograms(client_data) -> np.ndarray:
    """Label histograms from either corpus plane (cached, duck-typed) or
    a raw stacked dict."""
    cached = getattr(client_data, "label_histograms", None)
    if cached is not None:
        return cached()
    return label_histograms(np.asarray(client_data["y"]),
                            np.asarray(client_data["w"])
                            if "w" in client_data else None)


@register("selector", "pools")
class PoolSelector:
    """Epsilon-greedy over the paper's positive/negative device pools."""

    def __init__(self, num_clients: int, eps: float = 0.8, seed: int = 0):
        self.pools = DevicePools(num_clients, eps, seed)

    @classmethod
    def from_config(cls, config, local):
        return cls(config.num_clients, config.eps, config.seed)

    def select(self, num: int) -> list[int]:
        # clamp to the population like UniformSelector/QueueSelector do,
        # so the Selector surface owns the oversized-draw contract
        # (DevicePools guards internally too, but a config with
        # participation * num_clients > num_clients shouldn't depend on
        # that implementation detail)
        num = min(num, self.pools.num_devices)
        return self.pools.select(num)

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.pools.update(list(positives), list(negatives))

    def stats(self) -> dict:
        return self.pools.stats()


@register("selector", "pools-traced")
class TracedPoolSelector:
    """Epsilon-greedy pools on a ``jax.random`` stream — the scan-foldable
    twin of :class:`PoolSelector`.

    Selection semantics are the paper's (Alg. 2 lines 4-8/22: eps-greedy
    pool pick with spillover, cohort removed for the round, re-filed by
    verdict), but the draw is the pure jitted
    :func:`repro.core.pools.pools_draw` over (key, membership masks) —
    state the scan engine can carry on device through an R-round
    ``lax.scan``. Sequentially, :meth:`select`/:meth:`update` drive the
    identical jitted program one round at a time, so a folded block and
    the sequential ``Server`` produce bit-for-bit equal selection streams.

    The scan engine's fold surface:

    * :meth:`fold_carry` — the (key, pos_mask, neg_mask) device carry a
      block starts from;
    * :meth:`fold_drawn` — mirror one in-scan draw (cohort leaves the
      pools, the post-draw key is adopted); the engine then confirms the
      round with a normal :meth:`update`, exactly the sequential
      select/update cycle.
    """

    def __init__(self, num_clients: int, eps: float = 0.8, seed: int = 0):
        self.num_clients = int(num_clients)
        self.eps = float(eps)
        self._key = jax.random.PRNGKey(seed)
        self.positive: set[int] = set(range(self.num_clients))
        self.negative: set[int] = set()

    @classmethod
    def from_config(cls, config, local):
        return cls(config.num_clients, config.eps, config.seed)

    # ---- membership masks (the device representation) -------------------
    def _masks(self) -> tuple[jax.Array, jax.Array]:
        pos = np.zeros(self.num_clients, np.float32)
        neg = np.zeros(self.num_clients, np.float32)
        pos[sorted(self.positive)] = 1.0
        neg[sorted(self.negative)] = 1.0
        return jnp.asarray(pos), jnp.asarray(neg)

    def select(self, num: int) -> list[int]:
        num = min(num, self.num_clients)
        pos, neg = self._masks()
        sel, self._key = pools_draw(self._key, pos, neg,
                                    num=num, eps=self.eps)
        chosen = [int(c) for c in np.asarray(sel)]
        for c in chosen:            # removed for the round, like DevicePools
            self.positive.discard(c)
            self.negative.discard(c)
        return chosen

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.positive.update(int(i) for i in positives)
        self.negative.update(int(i) for i in negatives)

    # ---- scan-engine fold surface ---------------------------------------
    def fold_carry(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """(key, pos_mask, neg_mask) for the scan carry — the exact state
        the next sequential :meth:`select` would draw from."""
        pos, neg = self._masks()
        return self._key, pos, neg

    def fold_drawn(self, sel, key_after) -> None:
        """Mirror an in-scan draw the engine confirmed (or is about to
        replay eagerly): the cohort leaves both pools and the selector's
        key advances to the post-draw key stacked in the scan's ys."""
        for c in np.asarray(sel):
            self.positive.discard(int(c))
            self.negative.discard(int(c))
        self._key = jnp.asarray(key_after)

    def stats(self) -> dict:
        return {"selector": "pools-traced",
                "positive": len(self.positive),
                "negative": len(self.negative)}


@register("selector", "uniform")
class UniformSelector:
    """Uniform sampling without replacement; ignores judgment feedback."""

    def __init__(self, num_clients: int, seed: int = 0):
        self.num_clients = num_clients
        self._rng = np.random.default_rng(seed)

    @classmethod
    def from_config(cls, config, local):
        # seed + 1 keeps the draw stream identical to the legacy trainer's
        # use_pools=False path (its pool RNG held `seed`).
        return cls(config.num_clients, config.seed + 1)

    def select(self, num: int) -> list[int]:
        num = min(num, self.num_clients)
        return [int(i) for i in
                self._rng.choice(self.num_clients, num, replace=False)]

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        pass

    def stats(self) -> dict:
        # no pool bookkeeping exists; don't fabricate positive/negative
        # counts that could be mistaken for judgment outcomes
        return {"selector": "uniform", "num_clients": self.num_clients}


@register("selector", "catgroups")
class CatGrouper:
    """FedCAT device grouping over an inner selector (default uniform).

    ``select`` delegates to ``inner`` (so the draw stream — and therefore
    fixed-seed histories — matches the wrapped selector exactly), then
    packs the selection into ordered groups of ``group_size`` whose pooled
    label distributions are greedily entropy-maximized. The server binds
    the client corpus at construction (:meth:`bind_data`), which is where
    the per-device label histograms come from; an unbound grouper falls
    back to chaining devices in selection order.

    ``last_groups`` holds the current round's groups as lists of *relative*
    indices into the selection — the contract ``CatChainStrategy`` and
    ``DeviceConcatAggregator`` consume. Grouping is deterministic in the
    selection, so a speculative re-selection on a selector copy reproduces
    identical chains.
    """

    inner_cls = UniformSelector

    def __init__(self, inner, group_size: int = 2):
        self.inner = inner
        self.group_size = max(1, int(group_size))
        self._hists: np.ndarray | None = None
        self.last_groups: list[list[int]] | None = None

    @classmethod
    def from_config(cls, config, local):
        return cls(cls.inner_cls.from_config(config, local),
                   config.group_size)

    def bind_data(self, client_data) -> None:
        """Record per-device label histograms (corpus-cached when bound
        to a corpus of either plane, recomputed for a raw dict)."""
        self._hists = _corpus_histograms(client_data)

    def select(self, num: int) -> list[int]:
        sel = self.inner.select(num)
        if self._hists is not None:
            hists = self._hists[np.asarray(sel)]
        else:
            # unbound: degenerate one-class histograms -> groups chain the
            # selection in index order (still a valid partition)
            hists = np.ones((len(sel), 1))
        self.last_groups = greedy_entropy_groups(hists, self.group_size)
        return sel

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.inner.update(positives, negatives)

    def stats(self) -> dict:
        s = dict(self.inner.stats())
        s["group_size"] = self.group_size
        if self.last_groups is not None:
            s["num_groups"] = len(self.last_groups)
        return s


@register("selector", "catgroups-pools")
class PoolCatGrouper(CatGrouper):
    """CatGrouper over the paper's epsilon-greedy pools: judgment feedback
    re-files chain members, the synergy half of ``fedcat+maxent``."""

    inner_cls = PoolSelector


@register("selector", "queue")
class QueueSelector:
    """Entropy-driven participation with dynamic data queues
    (arXiv 2410.17792, heterogeneity cases per arXiv 2201.12515).

    Ranking: with probability ``eps`` the round exploits — the ``num``
    clients with the highest label-distribution entropy (read once off the
    bound corpus's cached histograms), fairness-damped by a per-selection
    ``fairness`` penalty so high-entropy clients don't monopolize rounds;
    otherwise it explores uniformly. Ties break to the lowest client id,
    so selection is a pure function of (rng stream, visit counts) and a
    speculative deepcopy replays it exactly.

    Queueing: every ``select`` advances a :class:`DataQueue` schedule and
    records each chosen client's released sample count;
    :meth:`data_schedule` hands those counts to the server, which masks
    them into the cohort's weight row inside the jitted corpus gather —
    the effective local dataset grows over training at zero transfer cost.

    Unbound (no corpus stats), selection degrades to uniform and the
    queue stays off — the selector never fabricates entropy ranks.
    """

    def __init__(self, num_clients: int, eps: float = 0.8, seed: int = 0,
                 queue: DataQueue | None = None, fairness: float = 0.05):
        self.num_clients = num_clients
        self.eps = eps
        self.fairness = fairness
        self.queue = queue or DataQueue()
        self._rng = np.random.default_rng(seed)
        self._uses = np.zeros(num_clients, np.int64)
        self._entropy: np.ndarray | None = None
        self._sizes: np.ndarray | None = None
        self._last_active: np.ndarray | None = None
        self._last_frac: float | None = None   # schedule last applied
        self.round_idx = 0
        self._pos = 0
        self._neg = 0

    @classmethod
    def from_config(cls, config, local):
        return cls(config.num_clients, config.eps, config.seed)

    def bind_data(self, client_data) -> None:
        """Pull per-client entropy ranks + real sizes off the corpus
        (either plane — the stats surface is duck-typed)."""
        if hasattr(client_data, "label_entropy"):
            self._entropy = client_data.label_entropy()
            self._sizes = client_data.sizes()
        else:
            hists = _corpus_histograms(client_data)
            self._entropy = np.asarray(
                [hist_entropy(h) for h in hists], np.float64)
            w = np.asarray(client_data["w"]) if "w" in client_data else None
            self._sizes = (np.full(len(hists), np.asarray(
                client_data["y"]).shape[1], np.int64) if w is None
                else w.sum(axis=1).astype(np.int64))

    def select(self, num: int) -> list[int]:
        num = min(num, self.num_clients)
        if self._entropy is not None and self._rng.random() < self.eps:
            score = self._entropy - self.fairness * self._uses
            order = np.lexsort((np.arange(self.num_clients), -score))
            sel = order[:num]
        else:
            sel = self._rng.choice(self.num_clients, num, replace=False)
        sel = [int(i) for i in sel]
        self._uses[sel] += 1
        if self._sizes is None:
            self._last_active = None
        else:
            self._last_active = self.queue.active(self.round_idx,
                                                  self._sizes[sel])
            self._last_frac = self.queue.frac(self.round_idx)
        self.round_idx += 1
        return sel

    def data_schedule(self, sel) -> np.ndarray | None:
        """Released-sample counts for the selection :meth:`select` just
        produced (the contract ``Server._run_cohort`` consumes); None
        until a corpus is bound."""
        return self._last_active

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self._pos += len(positives)
        self._neg += len(negatives)

    def stats(self) -> dict:
        # queue_frac is the schedule the LAST select actually applied —
        # None before any select (or while unbound, when the queue is
        # off), never a peek at the upcoming round's frac (the old
        # `frac(round_idx - 1)` reported round 0's frac at construction
        # as if a round had run)
        return {"selector": "queue", "round": self.round_idx,
                "queue_frac": self._last_frac,
                "positive_total": self._pos, "negative_total": self._neg}
