"""Selector implementations: who is asked to train this round.

``PoolSelector``    — the paper's epsilon-greedy positive/negative pools
                      (Alg. 2 lines 4-8/22), delegating to
                      ``core.pools.DevicePools``.
``UniformSelector`` — uniform sampling without replacement (the
                      ``use_pools=False`` ablation of Fig. 3b). Seeded with
                      ``seed + 1`` by the registry to match the legacy
                      trainer's RNG stream exactly.
``CatGrouper``      — FedCAT (arXiv 2202.12751) device grouping layered
                      over an inner selector: WHO trains is delegated, and
                      the selection is additionally packed into ordered
                      groups via ``core.pools.greedy_entropy_groups``;
                      ``catgroups`` wraps ``uniform`` (plain fedcat),
                      ``catgroups-pools`` wraps ``pools`` (fedcat+maxent).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..core.pools import DevicePools, greedy_entropy_groups, label_histograms
from .registry import register


@register("selector", "pools")
class PoolSelector:
    """Epsilon-greedy over the paper's positive/negative device pools."""

    def __init__(self, num_clients: int, eps: float = 0.8, seed: int = 0):
        self.pools = DevicePools(num_clients, eps, seed)

    @classmethod
    def from_config(cls, config, local):
        return cls(config.num_clients, config.eps, config.seed)

    def select(self, num: int) -> list[int]:
        return self.pools.select(num)

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.pools.update(list(positives), list(negatives))

    def stats(self) -> dict:
        return self.pools.stats()


@register("selector", "uniform")
class UniformSelector:
    """Uniform sampling without replacement; ignores judgment feedback."""

    def __init__(self, num_clients: int, seed: int = 0):
        self.num_clients = num_clients
        self._rng = np.random.default_rng(seed)

    @classmethod
    def from_config(cls, config, local):
        # seed + 1 keeps the draw stream identical to the legacy trainer's
        # use_pools=False path (its pool RNG held `seed`).
        return cls(config.num_clients, config.seed + 1)

    def select(self, num: int) -> list[int]:
        num = min(num, self.num_clients)
        return [int(i) for i in
                self._rng.choice(self.num_clients, num, replace=False)]

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        pass

    def stats(self) -> dict:
        # no pool bookkeeping exists; don't fabricate positive/negative
        # counts that could be mistaken for judgment outcomes
        return {"selector": "uniform", "num_clients": self.num_clients}


@register("selector", "catgroups")
class CatGrouper:
    """FedCAT device grouping over an inner selector (default uniform).

    ``select`` delegates to ``inner`` (so the draw stream — and therefore
    fixed-seed histories — matches the wrapped selector exactly), then
    packs the selection into ordered groups of ``group_size`` whose pooled
    label distributions are greedily entropy-maximized. The server binds
    the client corpus at construction (:meth:`bind_data`), which is where
    the per-device label histograms come from; an unbound grouper falls
    back to chaining devices in selection order.

    ``last_groups`` holds the current round's groups as lists of *relative*
    indices into the selection — the contract ``CatChainStrategy`` and
    ``DeviceConcatAggregator`` consume. Grouping is deterministic in the
    selection, so a speculative re-selection on a selector copy reproduces
    identical chains.
    """

    inner_cls = UniformSelector

    def __init__(self, inner, group_size: int = 2):
        self.inner = inner
        self.group_size = max(1, int(group_size))
        self._hists: np.ndarray | None = None
        self.last_groups: list[list[int]] | None = None

    @classmethod
    def from_config(cls, config, local):
        return cls(cls.inner_cls.from_config(config, local),
                   config.group_size)

    def bind_data(self, client_data: dict) -> None:
        """Record per-device label histograms from the stacked corpus."""
        self._hists = label_histograms(np.asarray(client_data["y"]),
                                       np.asarray(client_data["w"]))

    def select(self, num: int) -> list[int]:
        sel = self.inner.select(num)
        if self._hists is not None:
            hists = self._hists[np.asarray(sel)]
        else:
            # unbound: degenerate one-class histograms -> groups chain the
            # selection in index order (still a valid partition)
            hists = np.ones((len(sel), 1))
        self.last_groups = greedy_entropy_groups(hists, self.group_size)
        return sel

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.inner.update(positives, negatives)

    def stats(self) -> dict:
        s = dict(self.inner.stats())
        s["group_size"] = self.group_size
        if self.last_groups is not None:
            s["num_groups"] = len(self.last_groups)
        return s


@register("selector", "catgroups-pools")
class PoolCatGrouper(CatGrouper):
    """CatGrouper over the paper's epsilon-greedy pools: judgment feedback
    re-files chain members, the synergy half of ``fedcat+maxent``."""

    inner_cls = PoolSelector
