"""Selector implementations: who is asked to train this round.

``PoolSelector``    — the paper's epsilon-greedy positive/negative pools
                      (Alg. 2 lines 4-8/22), delegating to
                      ``core.pools.DevicePools``.
``UniformSelector`` — uniform sampling without replacement (the
                      ``use_pools=False`` ablation of Fig. 3b). Seeded with
                      ``seed + 1`` by the registry to match the legacy
                      trainer's RNG stream exactly.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..core.pools import DevicePools
from .registry import register


@register("selector", "pools")
class PoolSelector:
    """Epsilon-greedy over the paper's positive/negative device pools."""

    def __init__(self, num_clients: int, eps: float = 0.8, seed: int = 0):
        self.pools = DevicePools(num_clients, eps, seed)

    @classmethod
    def from_config(cls, config, local):
        return cls(config.num_clients, config.eps, config.seed)

    def select(self, num: int) -> list[int]:
        return self.pools.select(num)

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        self.pools.update(list(positives), list(negatives))

    def stats(self) -> dict:
        return self.pools.stats()


@register("selector", "uniform")
class UniformSelector:
    """Uniform sampling without replacement; ignores judgment feedback."""

    def __init__(self, num_clients: int, seed: int = 0):
        self.num_clients = num_clients
        self._rng = np.random.default_rng(seed)

    @classmethod
    def from_config(cls, config, local):
        # seed + 1 keeps the draw stream identical to the legacy trainer's
        # use_pools=False path (its pool RNG held `seed`).
        return cls(config.num_clients, config.seed + 1)

    def select(self, num: int) -> list[int]:
        num = min(num, self.num_clients)
        return [int(i) for i in
                self._rng.choice(self.num_clients, num, replace=False)]

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        pass

    def stats(self) -> dict:
        # no pool bookkeeping exists; don't fabricate positive/negative
        # counts that could be mistaken for judgment outcomes
        return {"selector": "uniform", "num_clients": self.num_clients}
