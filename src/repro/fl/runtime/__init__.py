"""``repro.fl.runtime`` — pipelined, sharded, and streaming round engines.

The same four composition axes as :class:`repro.fl.Server`, driven by
engines that (a) shard the stacked client axis over a ``("clients",)``
device mesh via ``shard_map``, (b) overlap the host-side float64
judgment oracle with the next round's client compute by speculating the
verdict on device (XLA or Pallas ``entropy_judge_sweep`` backends),
(c) optionally share compiled programs across servers through a bounded
process-level cache, and (d) — the async buffered engine — drop the
round barrier entirely: clients stream updates under a deterministic
simulated arrival clock, max-entropy judgment admits or rejects each
arrival against the buffered group, and flushes aggregate with
staleness-damped weights (see :mod:`.async_engine`).

Build through the registry::

    import repro.fl as fl
    from repro.fl.runtime import AsyncConfig, RuntimeConfig

    server = fl.build("fedentropy", apply_fn, params, data, config,
                      engine="pipelined",
                      runtime=RuntimeConfig(speculate=True,
                                            spec_backend="pallas"))
    streaming = fl.build("fedentropy", apply_fn, params, data, config,
                         engine="async",
                         runtime=AsyncConfig(clock="straggler",
                                             staleness_alpha=0.5))

With ``RuntimeConfig()`` defaults (no speculation, shard="auto") the
pipelined engine reproduces sequential ``Server`` round histories
bit-for-bit on fixed seeds (tests/test_runtime_engine.py); with
``AsyncConfig()`` defaults (K=|cohort|, zero-latency clock, damping off)
so does the async engine (tests/test_async_engine.py).
"""
from .async_engine import (
    ArrivalClock, AsyncBufferedServer, AsyncConfig, staleness_weights,
)
from .compile_cache import (
    ProcessCompileCache, disable_process_cache, enable_process_cache,
    process_cache,
)
from .engine import PipelinedServer, RuntimeConfig, SequentialEngine
from .scan_engine import ScanConfig, ScanServer
from .sharding import (
    CLIENT_AXIS, client_mesh_from, make_client_mesh, make_sharded_client_fn,
    pad_to_multiple,
)

__all__ = [
    "ArrivalClock", "AsyncBufferedServer", "AsyncConfig", "CLIENT_AXIS",
    "PipelinedServer", "ProcessCompileCache", "RuntimeConfig",
    "ScanConfig", "ScanServer", "SequentialEngine", "client_mesh_from",
    "disable_process_cache", "enable_process_cache", "make_client_mesh",
    "make_sharded_client_fn", "pad_to_multiple", "process_cache",
    "staleness_weights",
]
