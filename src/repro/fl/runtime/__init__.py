"""``repro.fl.runtime`` — pipelined, mesh-sharded execution engines.

The same four composition axes as :class:`repro.fl.Server`, driven by an
engine that (a) shards the stacked client axis over a ``("clients",)``
device mesh via ``shard_map``, (b) overlaps the host-side float64
judgment oracle with the next round's client compute by speculating the
verdict on device (XLA or Pallas ``entropy_judge_sweep`` backends), and
(c) optionally shares compiled programs across servers through a bounded
process-level cache.

Build through the registry::

    import repro.fl as fl
    from repro.fl.runtime import RuntimeConfig

    server = fl.build("fedentropy", apply_fn, params, data, config,
                      engine="pipelined",
                      runtime=RuntimeConfig(speculate=True,
                                            spec_backend="pallas"))

With ``RuntimeConfig()`` defaults (no speculation, shard="auto") the
engine reproduces sequential ``Server`` round histories bit-for-bit on
fixed seeds; see tests/test_runtime_engine.py.
"""
from .compile_cache import (
    ProcessCompileCache, disable_process_cache, enable_process_cache,
    process_cache,
)
from .engine import PipelinedServer, RuntimeConfig, SequentialEngine
from .sharding import (
    CLIENT_AXIS, client_mesh_from, make_client_mesh, make_sharded_client_fn,
    pad_to_multiple,
)

__all__ = [
    "CLIENT_AXIS", "PipelinedServer", "ProcessCompileCache", "RuntimeConfig",
    "SequentialEngine", "client_mesh_from", "disable_process_cache",
    "enable_process_cache", "make_client_mesh", "make_sharded_client_fn",
    "pad_to_multiple", "process_cache",
]
