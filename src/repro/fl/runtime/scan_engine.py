"""One-program rounds: R speculative rounds folded into a single scan.

Every engine so far surfaces to host once per round — selector draw,
float64 judgment, history append — and with the data plane resident
(PR 4/5) and the cohort gather traced, that per-round host round-trip is
the remaining serial cost. ``ScanServer`` (registry ``engine="scan"``,
``ScanConfig(rounds_per_scan=R)``) folds R whole rounds into ONE jitted
``lax.scan``: each scan step gathers its cohort from the resident corpus
(:meth:`repro.data.corpus.ClientCorpus.traced_cohort`), runs the
(sharded) ClientUpdate fan-out, *speculates the verdict on device* with
the traced float32 judge (``core.judgment.judge``; ``spec_backend=
"pallas"`` tiles the class axis through ``entropy_judge_sweep``), and
aggregates against the speculative mask — params are the scan carry, so
the host is touched exactly once per R rounds.

**Selection** (``ScanConfig.selection``):

* ``"replay"`` (default): the host pre-draws all R cohorts from the real
  ``UniformSelector`` before launching the scan and feeds them in as the
  scan's xs. Valid because a uniform draw is verdict-independent and its
  ``update`` is a no-op — the selector stream is *exactly* the stream
  the sequential ``Server`` would have drawn, which is what keeps golden
  histories equal.
* ``"device"``: a true on-device draw — the scan carries a JAX PRNG key
  and each step selects via ``jax.random.choice(..., replace=False)``.
  Histories then follow the device stream (reproducible per seed, but
  *not* comparable to the numpy selector's), so this mode is opt-in.

**Traced pool carry**: the paper's eps-greedy pools (the ``fedentropy``
default) couple each draw to the previous round's verdict, which used to
force R=1. A :class:`repro.fl.selectors.TracedPoolSelector`
(``selector="pools-traced"``) instead folds: the scan carries the pool
membership masks plus a ``jax.random`` key, each step draws via
:func:`repro.core.pools.pools_draw` and re-files via
:func:`~repro.core.pools.pools_refile` against the *speculated* verdict,
and the host selector mirror replays the confirmed draws
(:meth:`~repro.fl.selectors.TracedPoolSelector.fold_drawn` + ``update``)
so a folded block and the sequential ``Server`` walk identical selector
state — bit-for-bit equal histories. A misspeculated round truncates the
pool carry exactly like params: rounds after the first mismatch ran
against a wrong pool state and are discarded, the host mirror re-files
from the float64 oracle, and the continuation scan restarts from the
mirrored masks and the recorded post-draw key. (``selection`` is ignored
while pools fold — the pool draw *is* the on-device selection.)

**Memory** (``ScanConfig.params_mode``):

* ``"stack"`` (default): the scan's ys stack the post-round params every
  round — R rewind points, O(R * |params|) device memory. Fine for the
  paper CNN; fatal for LM pytrees.
* ``"remat"``: ys carry only the O(cohort * num_classes) verdict inputs
  (soft labels, sizes, selections, masks); on a mismatch at round j the
  rewind point is *rematerialized* by re-running rounds 0..j-1 through
  the same compiled step from the block's start carry — bounded
  recompute (< one extra block, only on the rare mismatch) instead of
  the R-fold params stash. Bitwise identical to ``"stack"``: the replay
  runs the identical ops on the identical inputs.

**Oracle replay** (the same bit-for-bit contract as ``PipelinedServer``):
after each scan the host casts the R stacked soft-label matrices to
float64 and replays the verdicts through the composition's own judge.
Recorded verdicts/entropy always come from that oracle. Rounds whose
speculative mask matches are confirmed wholesale (``spec_hit=True``); at
the first mismatch the block truncates — params rewind to the last
confirmed round's output, the mismatched round re-runs *eagerly* from
the oracle verdict exactly as the sequential ``Server`` would
(``spec_hit=False``), and the remaining rounds re-enter a fresh
(shorter) scan whose confirmed rounds carry ``redispatched=True``.

**Eligibility**: folding R>1 rounds without host contact requires every
per-round host dependency to be absent — a verdict-independent or traced
selector (``UniformSelector`` pre-draws; ``TracedPoolSelector`` folds;
the numpy ``PoolSelector``/queue/grouping selectors stay host-coupled),
a stateless strategy, no group dispatch (``prepare_round``), a traced
judge, and a resident data plane. Anything else falls back to
``rounds_per_scan=1`` — plain sequential rounds — with one loud log plus
machine-readable reasons (:attr:`ScanServer.fallback_reasons`, surfaced
in :meth:`ScanServer.stats` and on every fallback round's history record
under ``"scan_fallback"``), so every composition still *runs* under
``engine="scan"``; it just doesn't fold.

Block semantics: ``round()`` still returns one record at a time, but
params advance a whole block at once — an ``evaluate()`` between two
``round()`` calls of the same block sees the block-end model. Run
multiples of R rounds when comparing parameters mid-stream.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...core.aggregation import comm_bytes
from ...core.pools import pools_draw, pools_refile
from ..registry import register
from ..selectors import TracedPoolSelector, UniformSelector
from .engine import PipelinedServer, RuntimeConfig

log = logging.getLogger(__name__)

_SELECTION = ("replay", "device")
_PARAMS_MODES = ("stack", "remat")


@dataclass(frozen=True)
class ScanConfig:
    """Knobs for :class:`ScanServer` (the ``engine="scan"`` analog of
    ``RuntimeConfig``); R=1 reduces to the sequential ``Server`` exactly."""
    rounds_per_scan: int = 4      # R rounds folded per host surfacing
    spec_backend: str = "xla"     # traced in-scan judge: "xla" | "pallas"
    selection: str = "replay"     # "replay" (host pre-draw) | "device"
    params_mode: str = "stack"    # rewind points: "stack" ys | "remat" replay
    shard: object = "auto"        # forwarded to the inherited client fan-out
    donate_data: bool = True      # forwarded to the inherited client fan-out

    def __post_init__(self):
        if self.rounds_per_scan < 1:
            raise ValueError("rounds_per_scan must be >= 1")
        if self.selection not in _SELECTION:
            raise ValueError(f"unknown selection {self.selection!r}; "
                             f"expected one of {_SELECTION}")
        if self.params_mode not in _PARAMS_MODES:
            raise ValueError(f"unknown params_mode {self.params_mode!r}; "
                             f"expected one of {_PARAMS_MODES}")


@register("engine", "scan")
class ScanServer(PipelinedServer):
    """R-round ``lax.scan`` drop-in for ``Server`` (same composition axes).
    """

    runtime_cls = ScanConfig

    def __init__(self, *args, runtime: ScanConfig | None = None,
                 mesh=None, **kwargs):
        cfg = runtime if runtime is not None else ScanConfig()
        if not isinstance(cfg, ScanConfig):
            raise ValueError(
                f"ScanServer expects runtime=ScanConfig, got "
                f"{type(cfg).__name__} — RuntimeConfig belongs to the "
                "sequential/pipelined engines, AsyncConfig to async")
        # inherit the pipelined engine's sharded client fan-out and traced
        # judge; verdict speculation lives inside the scan, so the
        # pipelined per-round speculation stays off
        super().__init__(*args, runtime=RuntimeConfig(
            speculate=False, shard=cfg.shard,
            spec_backend=cfg.spec_backend, donate_data=cfg.donate_data),
            mesh=mesh, **kwargs)
        self.scan_config = cfg
        self._ready: list[dict] = []      # oracle-confirmed, un-popped recs
        self._scan_rounds: int | None = None   # resolved R_eff, once
        self.fallback_reasons: list[dict] | None = None  # set on resolve
        self._blocks = 0                  # scan programs launched
        self._mismatch_rounds = 0         # rounds replayed off the oracle
        self._key = (jax.random.PRNGKey(self.config.seed)
                     if cfg.selection == "device" else None)

    # -------------------------------------------------------- eligibility
    def _pool_fold(self) -> bool:
        """True when the selector is the traced-pools kind the scan can
        carry on device (exact class: a subclass may override semantics
        the fold replays)."""
        return type(self.selector) is TracedPoolSelector

    def scan_rounds(self) -> int:
        """Effective R: ``rounds_per_scan`` when the composition can fold,
        else 1 (sequential rounds; one loud log per server)."""
        if self._scan_rounds is None:
            self._scan_rounds = self._resolve_scan_rounds()
        return self._scan_rounds

    def _resolve_scan_rounds(self) -> int:
        R = self.scan_config.rounds_per_scan
        reasons: list[dict] = []
        if (type(self.selector) is not UniformSelector
                and not self._pool_fold()):
            reasons.append({
                "code": "verdict-coupled-selector",
                "component": type(self.selector).__name__,
                "detail": "the selector couples the next draw to the "
                          "previous verdict host-side; only "
                          "UniformSelector (verdict-independent) or "
                          "TracedPoolSelector (selector=\"pools-traced\", "
                          "the device-carried eps-greedy pools) fold"})
        if self.state is not None:
            reasons.append({
                "code": "stateful-strategy",
                "component": type(self.strategy).__name__,
                "detail": "the strategy carries cross-round client state "
                          "the scan cannot checkpoint per round"})
        if getattr(self.strategy, "prepare_round", None) is not None:
            reasons.append({
                "code": "group-dispatch",
                "component": type(self.strategy).__name__,
                "detail": "the strategy lays out whole device groups per "
                          "round (prepare_round)"})
        if not hasattr(self.corpus, "traced_cohort"):
            reasons.append({
                "code": "host-data-plane",
                "component": type(self.corpus).__name__,
                "detail": "the data plane has no traced gather (the "
                          "streaming HostCorpus gathers host-side)"})
        if self._traced_judge_fn() is None:
            reasons.append({
                "code": "untraced-judge",
                "component": type(self.judge).__name__,
                "detail": "the judge has no traced form"})
        if getattr(self, "bank", None) is not None:
            reasons.append({
                "code": "cluster-dispatch",
                "component": type(self.cluster).__name__,
                "detail": "clustered rounds assign clients to ModelBank "
                          "centers host-side every round (argmin over "
                          "jitted scores) and judge per cluster; the "
                          "scan cannot carry the K-center bank through "
                          "a host-free fold"})
        if self._drift:
            reasons.append({
                "code": "drift-schedule",
                "component": "DriftEvent",
                "detail": "a drift schedule rebuilds the corpus "
                          "mid-training; the scan's compiled step "
                          "captures the corpus at trace time, so folded "
                          "rounds would silently train on pre-drift "
                          "data"})
        self.fallback_reasons = reasons
        if R == 1:
            return 1
        if reasons:
            log.warning(
                "scan engine: falling back to rounds_per_scan=1 "
                "(sequential rounds) — %s",
                "; ".join(f"[{r['code']}] {r['component']}: {r['detail']}"
                          for r in reasons))
            return 1
        return R

    def stats(self) -> dict:
        """Machine-readable engine state: the effective fold depth, why a
        fold was refused (``fallback_reasons``, empty when folding), the
        memory mode, and block/mismatch counters."""
        self.scan_rounds()                       # resolve reasons once
        sel_stats = getattr(self.selector, "stats", dict)()
        return {
            "engine": "scan",
            "rounds_per_scan": self.scan_config.rounds_per_scan,
            "effective_rounds_per_scan": self.scan_rounds(),
            "fallback_reasons": [dict(r) for r in self.fallback_reasons],
            "params_mode": self.scan_config.params_mode,
            "selection": self.scan_config.selection,
            "pool_fold": self._pool_fold(),
            "blocks": self._blocks,
            "mismatch_rounds": self._mismatch_rounds,
            "selector": sel_stats,
        }

    # ------------------------------------------------------- scan program
    def _scan_fn(self, r: int):
        """One jitted program running ``r`` speculative rounds.

        ``block(params, key, pos, neg, rows) -> (params, key, pos, neg,
        ys)`` where ``rows`` is the (r, m) pre-drawn selection matrix
        (replay mode; inert otherwise), ``pos``/``neg`` are the pool
        membership masks (pool-fold mode; zero-length placeholders
        otherwise, which XLA drops), and ys stacks per round: the
        selection, raw soft labels + sizes (for the float64 oracle), the
        speculative mask, the post-draw PRNG key (pool-fold/device
        modes), and — in ``params_mode="stack"`` only — the post-round
        params (the truncation rewind points; ``"remat"`` rematerializes
        them on demand instead).
        """
        client = self._client_fn()        # shards the corpus if needed
        spec_fn = self._traced_judge_fn()
        agg = self.aggregator
        corpus = self.corpus
        pool_fold = self._pool_fold()
        on_device_sel = (self.scan_config.selection == "device"
                         and not pool_fold)
        stack_params = self.scan_config.params_mode == "stack"
        n_clients = self.config.num_clients
        m = min(self.config.cohort_size(), n_clients)
        eps = self.selector.eps if pool_fold else 0.0
        key = (("roundscan", r, self.scan_config.selection,
                self.scan_config.params_mode, pool_fold, eps,
                self.runtime.spec_backend, self.aggregator,
                self._shard_enabled()) + self._client_key())

        def make():
            def step(carry, xs):
                params, k, pos, neg = carry
                if pool_fold:
                    sel, k = pools_draw(k, pos, neg, num=m, eps=eps)
                elif on_device_sel:
                    k, sub = jax.random.split(k)
                    sel = jax.random.choice(
                        sub, n_clients, shape=(m,),
                        replace=False).astype(jnp.int32)
                else:
                    sel = xs
                data = corpus.traced_cohort(sel)
                out = client(params, data, None, None, None)
                sizes32 = out["size"].astype(jnp.float32)
                jr = spec_fn(out["soft_label"].astype(jnp.float32), sizes32)
                new_params = agg(params, out, sizes32, jr.mask)
                if pool_fold:
                    pos, neg = pools_refile(pos, neg, sel, jr.mask)
                ys = {"sel": sel, "soft": out["soft_label"],
                      "size": out["size"], "mask": jr.mask}
                if stack_params:
                    ys["params"] = new_params
                if pool_fold or on_device_sel:
                    ys["key"] = k
                return (new_params, k, pos, neg), ys

            def block(params, k, pos, neg, rows):
                (params, k, pos, neg), ys = jax.lax.scan(
                    step, (params, k, pos, neg), rows, length=r)
                return params, k, pos, neg, ys

            return jax.jit(block)
        return self._compile_cache().get(key, make)

    # ------------------------------------------------- memory introspection
    def block_ys_shapes(self, r: int | None = None) -> dict:
        """The stacked-ys pytree of a depth-``r`` block as
        ``jax.ShapeDtypeStruct`` leaves (via ``jax.eval_shape`` — nothing
        runs). ``params_mode="remat"`` blocks have no ``"params"`` entry:
        the per-round footprint is O(cohort * num_classes), independent
        of the model size."""
        R = int(r) if r is not None else self.scan_rounds()
        num = min(self.config.cohort_size(), self.config.num_clients)
        key, pos, neg = self._fold_state()
        rows = jnp.zeros((R, num), jnp.int32)
        out = jax.eval_shape(self._scan_fn(R), self.global_params,
                             key, pos, neg, rows)
        return out[4]

    def stacked_ys_nbytes(self, r: int | None = None) -> int:
        """Device bytes a depth-``r`` block's stacked ys would pin."""
        return int(sum(int(np.prod(s.shape)) * s.dtype.itemsize
                       for s in jax.tree.leaves(self.block_ys_shapes(r))))

    def _fold_state(self):
        """(key, pos_mask, neg_mask) carry for the current mode."""
        if self._pool_fold():
            return self.selector.fold_carry()
        dummy = jnp.zeros((0,), jnp.float32)
        key = (self._key if self._key is not None
               else jax.random.PRNGKey(0))          # inert in replay mode
        return key, dummy, dummy

    # ------------------------------------------------------------- rounds
    def round(self) -> dict:
        """One Alg. 2 round record; runs a whole R-round block when the
        confirmed-record buffer is empty."""
        if not self._ready:
            R = self.scan_rounds()
            if R == 1:
                rec = super().round()     # sequential (sharded) round
                if self.fallback_reasons:
                    # machine-readable on the record too (stats() has the
                    # full detail); extra keys are ignored by the golden
                    # comparators
                    rec["scan_fallback"] = [
                        r["code"] for r in self.fallback_reasons]
                return rec
            self._run_block(R)
        rec = self._ready.pop(0)
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def _run_block(self, R: int) -> None:
        cfg = self.config
        num = min(cfg.cohort_size(), cfg.num_clients)
        base = self.round_idx
        pool_fold = self._pool_fold()
        replay = (self.scan_config.selection == "replay"
                  and not pool_fold)
        remat = self.scan_config.params_mode == "remat"
        if replay:
            # pre-draw all R cohorts from the REAL selector: uniform draws
            # are verdict-independent and update() is a no-op, so this is
            # the exact stream the sequential interleaving would produce
            rows = np.stack([np.asarray(self.selector.select(num), np.int32)
                             for _ in range(R)])
        else:
            rows = np.zeros((R, num), np.int32)   # inert xs
        done = 0
        redispatched = False    # rounds re-scanned after a truncation
        params = self.global_params
        while done < R:
            r = R - done
            key, pos, neg = self._fold_state()
            seg = (params, key, pos, neg)     # remat rewind anchor
            seg_rows = jnp.asarray(rows[done:])
            params_out, key_out, pos_out, neg_out, ys = self._scan_fn(r)(
                params, key, pos, neg, seg_rows)
            self._blocks += 1
            soft_all = np.asarray(ys["soft"], np.float64)
            sizes_all = np.asarray(ys["size"], np.float64)
            masks_all = np.asarray(ys["mask"])
            sels_all = np.asarray(ys["sel"])
            keys_all = ys.get("key")

            mismatch_at = None
            for j in range(r):
                sel = [int(c) for c in sels_all[j]]
                a_rel, r_rel, ent = self.judge(soft_all[j], sizes_all[j])
                oracle = np.zeros(num, np.float32)
                oracle[a_rel] = 1.0
                if not np.array_equal(oracle, masks_all[j]):
                    mismatch_at = j
                    break
                pos_ids = [sel[i] for i in a_rel]
                neg_ids = [sel[i] for i in r_rel]
                if pool_fold:
                    # mirror the confirmed in-scan draw, then re-file —
                    # the exact sequential select/update cycle
                    self.selector.fold_drawn(sels_all[j], keys_all[j])
                self.selector.update(pos_ids, neg_ids)
                comm = comm_bytes(
                    self.global_params, len(sel), len(pos_ids),
                    soft_all.shape[-1],
                    control_variate=self.strategy.doubles_uplink)
                self._ready.append({
                    "round": base + done + j, "selected": sel,
                    "positive": pos_ids, "negative": neg_ids,
                    "entropy": ent, "comm": comm, "spec_hit": True,
                    "redispatched": redispatched})

            if mismatch_at is None:
                params = params_out
                if not replay:
                    self._key = key_out
                done += r
                continue

            # --- truncate: rewind params to the last confirmed round and
            #     redo the mismatched round eagerly from the oracle, then
            #     re-scan whatever rounds remain -------------------------
            j = mismatch_at
            self._mismatch_rounds += 1
            if j > 0:
                if remat:
                    # rematerialize the rewind point: re-run the j
                    # confirmed rounds through the SAME compiled step from
                    # the block's start carry — identical ops on identical
                    # inputs, so the result is bitwise the stacked
                    # ys["params"][j-1] of params_mode="stack"
                    params = self._scan_fn(j)(*seg, seg_rows[:j])[0]
                else:
                    params = jax.tree.map(lambda x: x[j - 1], ys["params"])
            if pool_fold:
                # the mismatched round's DRAW is valid (it depended only
                # on confirmed state); mirror it so the eager oracle
                # round's update() re-files against the right removal,
                # and adopt the post-draw key for the continuation
                self.selector.fold_drawn(sels_all[j], keys_all[j])
            elif not replay:
                # the continuation's draws chain from the carry key as it
                # stood AFTER round j's split
                self._key = ys["key"][j]
            params = self._oracle_round(
                params, sels_all[j], base + done + j)
            done += j + 1
            redispatched = True
        self.global_params = params

    def _oracle_round(self, start_params, sel, round_no: int):
        """The sequential round, replayed eagerly for a mismatched scan
        step: same select(ed cohort) -> ClientUpdate -> float64 oracle ->
        aggregate sequence as ``Server.round``, from ``start_params``."""
        cfg = self.config
        sel = [int(c) for c in np.asarray(sel)]
        out = self._run_cohort(sel, self.selector, start_params)
        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        a_rel, r_rel, ent = self.judge(soft, sizes)
        mask = np.zeros(len(sel), np.float32)
        mask[a_rel] = 1.0
        new_params = self.aggregator(
            start_params, out, jnp.asarray(sizes, jnp.float32),
            jnp.asarray(mask))
        self.state = self.strategy.update_state(
            self.state, start_params, out, np.asarray(sel),
            cfg.num_clients)
        pos = [sel[i] for i in a_rel]
        neg = [sel[i] for i in r_rel]
        self.selector.update(pos, neg)
        comm = comm_bytes(new_params, len(sel), len(pos), soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        self._ready.append({
            "round": round_no, "selected": sel, "positive": pos,
            "negative": neg, "entropy": ent, "comm": comm,
            "spec_hit": False, "redispatched": False})
        return new_params
