"""One-program rounds: R speculative rounds folded into a single scan.

Every engine so far surfaces to host once per round — selector draw,
float64 judgment, history append — and with the data plane resident
(PR 4/5) and the cohort gather traced, that per-round host round-trip is
the remaining serial cost. ``ScanServer`` (registry ``engine="scan"``,
``ScanConfig(rounds_per_scan=R)``) folds R whole rounds into ONE jitted
``lax.scan``: each scan step gathers its cohort from the resident corpus
(:meth:`repro.data.corpus.ClientCorpus.traced_cohort`), runs the
(sharded) ClientUpdate fan-out, *speculates the verdict on device* with
the traced float32 judge (``core.judgment.judge``; ``spec_backend=
"pallas"`` tiles the class axis through ``entropy_judge_sweep``), and
aggregates against the speculative mask — params are the scan carry, so
the host is touched exactly once per R rounds.

**Selection** (``ScanConfig.selection``):

* ``"replay"`` (default): the host pre-draws all R cohorts from the real
  ``UniformSelector`` before launching the scan and feeds them in as the
  scan's xs. Valid because a uniform draw is verdict-independent and its
  ``update`` is a no-op — the selector stream is *exactly* the stream
  the sequential ``Server`` would have drawn, which is what keeps golden
  histories equal.
* ``"device"``: a true on-device draw — the scan carries a JAX PRNG key
  and each step selects via ``jax.random.choice(..., replace=False)``.
  Histories then follow the device stream (reproducible per seed, but
  *not* comparable to the numpy selector's), so this mode is opt-in.

**Oracle replay** (the same bit-for-bit contract as ``PipelinedServer``):
after each scan the host casts the R stacked soft-label matrices to
float64 and replays the verdicts through the composition's own judge.
Recorded verdicts/entropy always come from that oracle. Rounds whose
speculative mask matches are confirmed wholesale (``spec_hit=True``); at
the first mismatch the block truncates — params rewind to the last
confirmed round's output (stacked per-round in the scan's ys), the
mismatched round re-runs *eagerly* from the oracle verdict exactly as the
sequential ``Server`` would (``spec_hit=False``), and the remaining
pre-drawn cohorts re-enter a fresh (shorter) scan whose confirmed rounds
carry ``redispatched=True``.

**Eligibility**: folding R>1 rounds without host contact requires every
per-round host dependency to be absent — a ``UniformSelector`` (stateful
pool/queue/grouping selectors couple the next draw to the previous
verdict), a stateless strategy (no cross-round client state to carry), no
group dispatch (``prepare_round``), a traced judge, and a resident data
plane (the streaming ``HostCorpus`` gathers host-side). Anything else
falls back to ``rounds_per_scan=1`` — plain sequential rounds — with one
loud log, so every composition still *runs* under ``engine="scan"`` and
the goldens still hold; it just doesn't fold.

Block semantics: ``round()`` still returns one record at a time, but
params advance a whole block at once — an ``evaluate()`` between two
``round()`` calls of the same block sees the block-end model. Run
multiples of R rounds when comparing parameters mid-stream.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...core.aggregation import comm_bytes
from ..registry import register
from ..selectors import UniformSelector
from .engine import PipelinedServer, RuntimeConfig

log = logging.getLogger(__name__)

_SELECTION = ("replay", "device")


@dataclass(frozen=True)
class ScanConfig:
    """Knobs for :class:`ScanServer` (the ``engine="scan"`` analog of
    ``RuntimeConfig``); R=1 reduces to the sequential ``Server`` exactly."""
    rounds_per_scan: int = 4      # R rounds folded per host surfacing
    spec_backend: str = "xla"     # traced in-scan judge: "xla" | "pallas"
    selection: str = "replay"     # "replay" (host pre-draw) | "device"
    shard: object = "auto"        # forwarded to the inherited client fan-out
    donate_data: bool = True      # forwarded to the inherited client fan-out

    def __post_init__(self):
        if self.rounds_per_scan < 1:
            raise ValueError("rounds_per_scan must be >= 1")
        if self.selection not in _SELECTION:
            raise ValueError(f"unknown selection {self.selection!r}; "
                             f"expected one of {_SELECTION}")


@register("engine", "scan")
class ScanServer(PipelinedServer):
    """R-round ``lax.scan`` drop-in for ``Server`` (same composition axes).
    """

    runtime_cls = ScanConfig

    def __init__(self, *args, runtime: ScanConfig | None = None,
                 mesh=None, **kwargs):
        cfg = runtime if runtime is not None else ScanConfig()
        if not isinstance(cfg, ScanConfig):
            raise ValueError(
                f"ScanServer expects runtime=ScanConfig, got "
                f"{type(cfg).__name__} — RuntimeConfig belongs to the "
                "sequential/pipelined engines, AsyncConfig to async")
        # inherit the pipelined engine's sharded client fan-out and traced
        # judge; verdict speculation lives inside the scan, so the
        # pipelined per-round speculation stays off
        super().__init__(*args, runtime=RuntimeConfig(
            speculate=False, shard=cfg.shard,
            spec_backend=cfg.spec_backend, donate_data=cfg.donate_data),
            mesh=mesh, **kwargs)
        self.scan_config = cfg
        self._ready: list[dict] = []      # oracle-confirmed, un-popped recs
        self._scan_rounds: int | None = None   # resolved R_eff, once
        self._key = (jax.random.PRNGKey(self.config.seed)
                     if cfg.selection == "device" else None)

    # -------------------------------------------------------- eligibility
    def scan_rounds(self) -> int:
        """Effective R: ``rounds_per_scan`` when the composition can fold,
        else 1 (sequential rounds; one loud log per server)."""
        if self._scan_rounds is None:
            self._scan_rounds = self._resolve_scan_rounds()
        return self._scan_rounds

    def _resolve_scan_rounds(self) -> int:
        R = self.scan_config.rounds_per_scan
        if R == 1:
            return 1
        reasons = []
        if type(self.selector) is not UniformSelector:
            reasons.append(
                f"selector {type(self.selector).__name__} couples the "
                "next draw to the previous verdict (pools/queue/groups); "
                "only UniformSelector draws are verdict-independent")
        if self.state is not None:
            reasons.append(
                f"strategy {type(self.strategy).__name__} carries "
                "cross-round client state the scan cannot checkpoint "
                "per round")
        if getattr(self.strategy, "prepare_round", None) is not None:
            reasons.append(
                f"strategy {type(self.strategy).__name__} lays out whole "
                "device groups per round (prepare_round)")
        if not hasattr(self.corpus, "traced_cohort"):
            reasons.append(
                "the data plane has no traced gather (the streaming "
                "HostCorpus gathers host-side)")
        if self._traced_judge_fn() is None:
            reasons.append(
                f"judge {type(self.judge).__name__} has no traced form")
        if reasons:
            log.warning(
                "scan engine: falling back to rounds_per_scan=1 "
                "(sequential rounds) — %s", "; ".join(reasons))
            return 1
        return R

    # ------------------------------------------------------- scan program
    def _scan_fn(self, r: int):
        """One jitted program running ``r`` speculative rounds.

        ``block(params, key, rows) -> (params, key, ys)`` where ``rows``
        is the (r, m) pre-drawn selection matrix (replay mode; ignored in
        device mode) and ys stacks per round: the selection, raw soft
        labels + sizes (for the float64 oracle), the speculative mask,
        the post-round params (the truncation rewind points) and — in
        device mode — the post-draw PRNG key.
        """
        client = self._client_fn()        # shards the corpus if needed
        spec_fn = self._traced_judge_fn()
        agg = self.aggregator
        corpus = self.corpus
        on_device_sel = self.scan_config.selection == "device"
        n_clients = self.config.num_clients
        m = min(self.config.cohort_size(), n_clients)
        key = (("roundscan", r, self.scan_config.selection,
                self.runtime.spec_backend, self.aggregator,
                self._shard_enabled()) + self._client_key())

        def make():
            def step(carry, xs):
                params, k = carry
                if on_device_sel:
                    k, sub = jax.random.split(k)
                    sel = jax.random.choice(
                        sub, n_clients, shape=(m,),
                        replace=False).astype(jnp.int32)
                else:
                    sel = xs
                data = corpus.traced_cohort(sel)
                out = client(params, data, None, None, None)
                sizes32 = out["size"].astype(jnp.float32)
                jr = spec_fn(out["soft_label"].astype(jnp.float32), sizes32)
                new_params = agg(params, out, sizes32, jr.mask)
                ys = {"sel": sel, "soft": out["soft_label"],
                      "size": out["size"], "mask": jr.mask,
                      "params": new_params}
                if on_device_sel:
                    ys["key"] = k
                return (new_params, k), ys

            def block(params, k, rows):
                xs = None if on_device_sel else rows
                (params, k), ys = jax.lax.scan(step, (params, k), xs,
                                               length=r)
                return params, k, ys

            return jax.jit(block)
        return self._compile_cache().get(key, make)

    # ------------------------------------------------------------- rounds
    def round(self) -> dict:
        """One Alg. 2 round record; runs a whole R-round block when the
        confirmed-record buffer is empty."""
        if not self._ready:
            R = self.scan_rounds()
            if R == 1:
                return super().round()    # sequential (sharded) round
            self._run_block(R)
        rec = self._ready.pop(0)
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def _run_block(self, R: int) -> None:
        cfg = self.config
        num = min(cfg.cohort_size(), cfg.num_clients)
        base = self.round_idx
        replay = self.scan_config.selection == "replay"
        if replay:
            # pre-draw all R cohorts from the REAL selector: uniform draws
            # are verdict-independent and update() is a no-op, so this is
            # the exact stream the sequential interleaving would produce
            rows = np.stack([np.asarray(self.selector.select(num), np.int32)
                             for _ in range(R)])
            key = jax.random.PRNGKey(0)    # inert carry
        else:
            rows = np.zeros((R, num), np.int32)   # inert xs
            key = self._key
        done = 0
        redispatched = False    # rounds re-scanned after a truncation
        params = self.global_params
        while done < R:
            r = R - done
            params_out, key_out, ys = self._scan_fn(r)(
                params, key, jnp.asarray(rows[done:]))
            soft_all = np.asarray(ys["soft"], np.float64)
            sizes_all = np.asarray(ys["size"], np.float64)
            masks_all = np.asarray(ys["mask"])
            sels_all = np.asarray(ys["sel"])

            mismatch_at = None
            for j in range(r):
                sel = [int(c) for c in sels_all[j]]
                a_rel, r_rel, ent = self.judge(soft_all[j], sizes_all[j])
                oracle = np.zeros(num, np.float32)
                oracle[a_rel] = 1.0
                if not np.array_equal(oracle, masks_all[j]):
                    mismatch_at = j
                    break
                pos = [sel[i] for i in a_rel]
                neg = [sel[i] for i in r_rel]
                self.selector.update(pos, neg)
                comm = comm_bytes(
                    self.global_params, len(sel), len(pos),
                    soft_all.shape[-1],
                    control_variate=self.strategy.doubles_uplink)
                self._ready.append({
                    "round": base + done + j, "selected": sel,
                    "positive": pos, "negative": neg, "entropy": ent,
                    "comm": comm, "spec_hit": True,
                    "redispatched": redispatched})

            if mismatch_at is None:
                params, key = params_out, key_out
                done += r
                continue

            # --- truncate: rewind params to the last confirmed round and
            #     redo the mismatched round eagerly from the oracle, then
            #     re-scan whatever pre-drawn cohorts remain -------------
            j = mismatch_at
            if j > 0:
                params = jax.tree.map(lambda x: x[j - 1], ys["params"])
            if not replay:
                # the continuation's draws chain from the carry key as it
                # stood AFTER round j's split
                key = ys["key"][j]
            params = self._oracle_round(
                params, sels_all[j], base + done + j)
            done += j + 1
            redispatched = True
        self.global_params = params
        if not replay:
            self._key = key

    def _oracle_round(self, start_params, sel, round_no: int):
        """The sequential round, replayed eagerly for a mismatched scan
        step: same select(ed cohort) -> ClientUpdate -> float64 oracle ->
        aggregate sequence as ``Server.round``, from ``start_params``."""
        cfg = self.config
        sel = [int(c) for c in np.asarray(sel)]
        out = self._run_cohort(sel, self.selector, start_params)
        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        a_rel, r_rel, ent = self.judge(soft, sizes)
        mask = np.zeros(len(sel), np.float32)
        mask[a_rel] = 1.0
        new_params = self.aggregator(
            start_params, out, jnp.asarray(sizes, jnp.float32),
            jnp.asarray(mask))
        self.state = self.strategy.update_state(
            self.state, start_params, out, np.asarray(sel),
            cfg.num_clients)
        pos = [sel[i] for i in a_rel]
        neg = [sel[i] for i in r_rel]
        self.selector.update(pos, neg)
        comm = comm_bytes(new_params, len(sel), len(pos), soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        self._ready.append({
            "round": round_no, "selected": sel, "positive": pos,
            "negative": neg, "entropy": ent, "comm": comm,
            "spec_hit": False, "redispatched": False})
        return new_params
