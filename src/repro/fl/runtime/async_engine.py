"""The async buffered round engine: FedBuff-style streaming + judgment.

Both round-synchronous engines gate every aggregation on the slowest
client in the cohort. ``AsyncBufferedServer`` drops that barrier: clients
stream their finished updates under a deterministic *simulated* arrival
clock (a seeded per-client latency model — pure virtual time, never the
wall clock), each arriving update passes the paper's max-entropy judgment
as an **admission filter** against the already-admitted buffer
(:meth:`repro.fl.judges.MaxEntropyJudge.admit` — buffered rows are
protected: their weights already shipped), and the server aggregates a
*flush* whenever ``AsyncConfig.buffer_size`` arrivals have been screened.
Admitted updates aggregate with staleness-damped weights (FedBuff's
polynomial damping ``(1 + τ)^-α`` with τ = flushes elapsed since the
update's model version); rejected updates are dropped *before* shipping
weights — the paper's "don't collect harmful models" rule applied
per-arrival, which is where the uplink savings over round-synchronous
FedAvg come from (see ``benchmarks/async_throughput.py``).

The engine reuses the whole existing data plane: cohorts are dispatched
through the device-resident ``ClientCorpus`` gather and the pipelined
engine's shard_map client fan-out (it subclasses ``PipelinedServer`` for
exactly that ``_client_fn``), so a dispatch is one on-device gather +
vmapped/sharded ClientUpdate regardless of mesh size.

**Reduction guarantee** (tested bit-for-bit in tests/test_async_engine.py
against both a live sequential ``Server`` and the recorded goldens): with
``buffer_size = |cohort|``, the zero-latency clock, and damping off, every
dispatch arrives as one simultaneous batch, admission over the empty
buffer *is* the sequential round judgment (float64 oracle), and the flush
replays ``Server.round``'s exact aggregate/state/selector sequence — so
histories and parameters equal the sequential engine's exactly.

Determinism: the only random streams are the selector's (advanced exactly
once per dispatched cohort) and the latency model's own
``np.random.default_rng(AsyncConfig.seed)``; arrival ties break by
dispatch order. Same seeds → identical flush histories, always.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...core.aggregation import comm_bytes
from ..judges import admit_candidates
from ..registry import register
from .engine import PipelinedServer, RuntimeConfig

_CLOCKS = ("zero", "uniform", "straggler")


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs for :class:`AsyncBufferedServer` (the ``engine="async"``
    analog of ``RuntimeConfig``; the defaults reduce to the sequential
    ``Server`` exactly — see the module docstring)."""
    buffer_size: int = 0          # K screened arrivals per flush; 0=|cohort|
    staleness_alpha: float = 0.0  # (1+τ)^-α damping; 0 disables exactly
    clock: str = "zero"           # "zero" | "uniform" | "straggler"
    latency_scale: float = 1.0    # mean-ish per-update latency (virtual)
    straggler_frac: float = 0.125  # fraction of clients that straggle
    straggler_factor: float = 16.0  # stragglers' latency multiplier
    seed: int = 0                 # latency model stream (not the selector's)
    concurrency: int = 0          # in-flight update target; 0=|cohort|
    shard: object = "auto"        # forwarded to the inherited client fan-out
    donate_data: bool = True      # forwarded to the inherited client fan-out

    def __post_init__(self):
        if self.clock not in _CLOCKS:
            raise ValueError(
                f"unknown clock {self.clock!r}; expected one of {_CLOCKS}")
        if self.buffer_size < 0:
            raise ValueError("buffer_size must be >= 0 (0 = cohort size)")
        if self.staleness_alpha < 0:
            raise ValueError("staleness_alpha must be >= 0")
        if self.latency_scale < 0:
            raise ValueError("latency_scale must be >= 0")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError("straggler_frac must be in [0, 1]")
        if self.straggler_factor < 1.0:
            raise ValueError("straggler_factor must be >= 1")
        if self.concurrency < 0:
            raise ValueError("concurrency must be >= 0 (0 = cohort size)")


def staleness_weights(tau, alpha: float) -> np.ndarray:
    """FedBuff's polynomial staleness damping: ``(1 + τ)^-α`` (float64).

    Monotone non-increasing in τ for α > 0; identically 1 at α = 0
    (tests/test_async_properties.py holds both by property).
    """
    tau = np.asarray(tau, np.float64)
    if np.any(tau < 0):
        raise ValueError("staleness must be >= 0")
    return np.power(1.0 + tau, -float(alpha))


class ArrivalClock:
    """Deterministic per-client latency model over *virtual* time.

    Latencies are drawn once at construction from
    ``np.random.default_rng(cfg.seed)`` — "zero" is all-zeros (every
    dispatch arrives instantly, as one batch), "uniform" is
    ``latency_scale * U(0.5, 1.5)`` per client, and "straggler" starts
    from uniform then multiplies a ``straggler_frac`` subset by
    ``straggler_factor`` (the heavy-tail IoT regime the benchmarks
    stress). An update dispatched at virtual time t arrives at
    ``t + latency[client]`` — no wall-clock reads anywhere.
    """

    def __init__(self, cfg: AsyncConfig, num_clients: int):
        rng = np.random.default_rng(cfg.seed)
        if cfg.clock == "zero":
            lat = np.zeros(num_clients, np.float64)
        else:
            lat = cfg.latency_scale * rng.uniform(0.5, 1.5, num_clients)
            if cfg.clock == "straggler":
                k = int(round(cfg.straggler_frac * num_clients))
                if k:
                    slow = rng.choice(num_clients, size=k, replace=False)
                    lat[slow] *= cfg.straggler_factor
        self.latency = lat

    def arrival(self, client: int, t_dispatch: float) -> float:
        return float(t_dispatch + self.latency[client])


@register("engine", "async")
class AsyncBufferedServer(PipelinedServer):
    """Streaming drop-in for ``Server``: ``round()`` == one buffer flush."""

    runtime_cls = AsyncConfig

    def __init__(self, *args, runtime: AsyncConfig | None = None,
                 mesh=None, **kwargs):
        cfg = runtime if runtime is not None else AsyncConfig()
        if not isinstance(cfg, AsyncConfig):
            raise ValueError(
                f"AsyncBufferedServer expects runtime=AsyncConfig, got "
                f"{type(cfg).__name__} — RuntimeConfig belongs to the "
                "sequential/pipelined engines")
        # inherit the pipelined engine's sharded client fan-out; the async
        # engine replaces round structure, not client compute, so verdict
        # speculation never applies here
        super().__init__(*args, runtime=RuntimeConfig(
            speculate=False, shard=cfg.shard, donate_data=cfg.donate_data),
            mesh=mesh, **kwargs)
        if getattr(self.strategy, "prepare_round", None) is not None:
            raise ValueError(
                f"{type(self.strategy).__name__} lays out whole device "
                "groups per round (prepare_round); the async engine "
                "screens single arrivals and cannot honor group dispatch "
                "yet — use the sequential or pipelined engine (async + "
                "fedcat groups is a recorded ROADMAP follow-up)")
        if getattr(self, "bank", None) is not None:
            raise ValueError(
                f"{type(self.cluster).__name__} carries a K-center "
                "ModelBank; the async engine's per-arrival admission has "
                "no per-cluster buffer semantics yet — use the sequential "
                "or pipelined engine (async + clusters is a recorded "
                "ROADMAP follow-up)")
        if self._drift:
            raise ValueError(
                "the async engine's in-flight arrival heap holds updates "
                "computed against the dispatch-time corpus; a drift "
                "schedule would mix pre- and post-drift arrivals in one "
                "flush — use the sequential or pipelined engine for "
                "drifted runs")
        self.async_config = cfg
        self.clock = ArrivalClock(cfg, self.config.num_clients)
        self._events: list[tuple] = []   # heap of (t_arrival, seq, entry)
        self._seq = 0                    # global dispatch counter (tiebreak)
        self._vtime = 0.0                # virtual now = last arrival seen
        self._buffer: list[dict] = []    # admitted, not yet flushed
        self._flush_log: list[dict] = []  # screened this window, arrival order
        self._pos_log: list[int] = []    # admitted client ids, arrival order
        self._neg_log: list[int] = []    # rejected client ids, removal order
        self._last_ent = float("nan")    # entropy after latest screening

    # ------------------------------------------------------------- sizing
    def _cohort_size(self) -> int:
        return self.config.cohort_size()

    @property
    def buffer_size(self) -> int:
        k = self.async_config.buffer_size
        return k if k > 0 else self._cohort_size()

    def _concurrency_target(self) -> int:
        c = self.async_config.concurrency
        return c if c > 0 else self._cohort_size()

    # ------------------------------------------------------------- stream
    def _dispatch_cohort(self) -> None:
        """Select a cohort, launch its (sharded) client compute, and put
        each member's finished update on the arrival heap.

        The dispatch unit stays a full cohort — one compiled program shape,
        one on-device corpus gather — but arrivals are *per client*: each
        row of the cohort output becomes its own event at
        ``vtime + latency[client]``, stamped with the current model version
        for staleness accounting. Soft labels sync to host here (they ship
        with every selected client in the comm model; only admitted clients
        later ship weights).
        """
        sel = self.selector.select(self._cohort_size())
        out = self._run_cohort(sel, self.selector)
        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        for row, client in enumerate(sel):
            entry = {"client": int(client), "row": row, "out": out,
                     "soft": soft[row], "size": float(sizes[row]),
                     "version": self.round_idx, "seq": self._seq,
                     "t_arr": self.clock.arrival(client, self._vtime)}
            heapq.heappush(self._events, (entry["t_arr"], self._seq, entry))
            self._seq += 1

    def _ensure_inflight(self) -> None:
        target = self._concurrency_target()
        while len(self._events) < target:
            self._dispatch_cohort()

    def _pop_batch(self) -> list[dict]:
        """Pop every event sharing the next arrival instant (ties break by
        dispatch order, so the zero-latency clock yields whole cohorts in
        selection order — the reduction case)."""
        t, _, entry = heapq.heappop(self._events)
        self._vtime = max(self._vtime, t)
        batch = [entry]
        while self._events and self._events[0][0] == t:
            batch.append(heapq.heappop(self._events)[2])
        return batch

    def _screen(self, batch: list[dict]) -> None:
        """Max-entropy admission of one arrival batch against the buffer."""
        cand_soft = np.stack([e["soft"] for e in batch])
        cand_sizes = np.asarray([e["size"] for e in batch], np.float64)
        if self._buffer:
            buf_soft = np.stack([e["soft"] for e in self._buffer])
            buf_sizes = np.asarray([e["size"] for e in self._buffer],
                                   np.float64)
        else:
            buf_soft = np.zeros((0, cand_soft.shape[1]), np.float64)
            buf_sizes = np.zeros((0,), np.float64)
        admit = getattr(self.judge, "admit", None)
        if admit is None:
            a_rel, r_rel, ent = admit_candidates(
                self.judge, buf_soft, buf_sizes, cand_soft, cand_sizes)
        else:
            a_rel, r_rel, ent = admit(buf_soft, buf_sizes,
                                      cand_soft, cand_sizes)
        admitted = set(a_rel)
        for i, entry in enumerate(batch):
            entry["admitted"] = i in admitted
            self._flush_log.append(entry)
        self._buffer.extend(batch[i] for i in a_rel)
        self._pos_log.extend(batch[i]["client"] for i in a_rel)
        self._neg_log.extend(batch[i]["client"] for i in r_rel)
        self._last_ent = ent

    # -------------------------------------------------------------- flush
    def _flush(self) -> dict:
        """Aggregate the screened window; replays ``Server.round``'s exact
        aggregate → state → selector sequence over the arrival-ordered
        rows, so the K=|cohort| zero-latency case is bit-for-bit the
        sequential round."""
        cfg = self.config
        log = self._flush_log
        sel = [e["client"] for e in log]
        idx = np.asarray(sel)
        rows = [jax.tree.map(lambda x, r=e["row"]: x[r], e["out"])
                for e in log]
        out = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        sizes = np.asarray([e["size"] for e in log], np.float64)
        mask = np.asarray([1.0 if e["admitted"] else 0.0 for e in log],
                          np.float32)
        tau = np.asarray([self.round_idx - e["version"] for e in log],
                         np.int64)
        alpha = self.async_config.staleness_alpha
        # α==0 skips the damping multiply entirely: the reduction must hand
        # the aggregator the float64 sizes Server.round hands it, untouched
        weights = sizes if alpha == 0.0 else \
            sizes * staleness_weights(tau, alpha)

        new_global = self.aggregator(
            self.global_params, out,
            jnp.asarray(weights, jnp.float32), jnp.asarray(mask))
        self.state = self.strategy.update_state(
            self.state, self.global_params, out, idx, cfg.num_clients)
        self.global_params = new_global

        pos, neg = self._pos_log, self._neg_log
        self.selector.update(pos, neg)
        # staleness feedback plumbing: selectors exposing
        # ``observe_staleness`` see each screened arrival's τ (flushes
        # elapsed since its dispatch version) alongside the verdict, in
        # arrival order — the hook a staleness-aware selector would rank
        # on. Pure observation: no built-in selector defines it, so the
        # default stream (and the sequential reduction) is untouched.
        observe = getattr(self.selector, "observe_staleness", None)
        if observe is not None:
            observe([{"client": e["client"], "staleness": int(t),
                      "admitted": bool(e["admitted"])}
                     for e, t in zip(log, tau)])

        comm = comm_bytes(self.global_params, len(sel), len(pos),
                          log[0]["soft"].shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": self._last_ent, "comm": comm,
               # async extras: the sequential record plus stream telemetry
               "flush_time": float(self._vtime),
               "staleness": [int(t) for t in tau],
               "buffer_occupancy": len(self._buffer),
               "inflight": len(self._events),
               "seq": [e["seq"] for e in log],
               "admitted_seq": [e["seq"] for e in log if e["admitted"]]}
        self.history.append(rec)
        self.round_idx += 1
        self._buffer, self._flush_log = [], []
        self._pos_log, self._neg_log = [], []
        self._last_ent = float("nan")
        return rec

    # ------------------------------------------------------------- rounds
    def round(self) -> dict:
        """Advance virtual time until ``buffer_size`` arrivals have been
        screened, then flush. A simultaneous arrival batch is screened
        whole, so a flush can exceed K by the tie overshoot (the zero
        clock flushes exact cohorts)."""
        k = self.buffer_size
        while len(self._flush_log) < k:
            self._ensure_inflight()
            self._screen(self._pop_batch())
        return self._flush()
