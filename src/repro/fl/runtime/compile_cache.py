"""Opt-in process-level bounded compile cache (ROADMAP item).

Benchmark sweeps build hundreds of ``Server``s over the same
(apply_fn, LocalSpec, client-data shapes) and — with the per-server
``BoundedJitCache`` default — recompile the vmapped ClientUpdate for every
one of them. Enabling this cache restores cross-server sharing without
unbounded growth: one process-global LRU keyed on
``(tag, apply_fn, spec, in_axes, shapes)`` (the keys
``Server._client_key`` builds — the apply_fn participates by identity
and is pinned by the entry, so object-address reuse can never alias a
stale program), bounded at ``maxsize`` entries.

Usage::

    from repro.fl.runtime import enable_process_cache
    cache = enable_process_cache(maxsize=32)
    ... build/run many servers ...
    print(cache.stats())            # {"hits": ..., "misses": ..., ...}
    disable_process_cache()

The per-server cache stays the default because process-level sharing keys
on apply_fn identity: callers that rebuild closures per server get no
sharing (each closure is its own key); callers that hold one apply_fn get
full sharing. Both caches are thread-safe and build *outside* the lock
with per-key once semantics (see ``BoundedJitCache.get``): the streaming
data plane's cohort prefetcher runs on a background thread, and a
multi-second XLA compile on the round thread must not stall it.
"""
from __future__ import annotations

from typing import Optional

from ..server import BoundedJitCache


class ProcessCompileCache(BoundedJitCache):
    """Bounded LRU shared by every Server in the process, with hit stats."""

    def __init__(self, maxsize: int = 32):
        super().__init__(maxsize)
        self.hits = 0
        self.misses = 0

    def _record(self, hit: bool) -> None:
        # runs under the base class's lock, on the hit probe and on the
        # builder's insert — waiters that adopt a concurrent build count
        # as hits, so racing threads on one key record exactly one miss
        if hit:
            self.hits += 1
        else:
            self.misses += 1

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self), "maxsize": self.maxsize}


_PROCESS_CACHE: Optional[ProcessCompileCache] = None


def enable_process_cache(maxsize: int = 32) -> ProcessCompileCache:
    """Turn on process-level compiled-program sharing; returns the cache.

    Re-enabling with a different ``maxsize`` rebounds (and trims) the
    existing cache rather than dropping compiled programs.
    """
    global _PROCESS_CACHE
    if _PROCESS_CACHE is None:
        _PROCESS_CACHE = ProcessCompileCache(maxsize)
    else:
        with _PROCESS_CACHE._lock:
            _PROCESS_CACHE.maxsize = max(1, int(maxsize))
            while len(_PROCESS_CACHE._entries) > _PROCESS_CACHE.maxsize:
                _PROCESS_CACHE._entries.popitem(last=False)
    return _PROCESS_CACHE


def disable_process_cache() -> None:
    """Drop the process cache; servers fall back to their per-server LRUs."""
    global _PROCESS_CACHE
    _PROCESS_CACHE = None


def process_cache() -> Optional[ProcessCompileCache]:
    """The active process-level cache, or None when disabled (default)."""
    return _PROCESS_CACHE
