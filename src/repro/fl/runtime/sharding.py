"""Mesh-sharded client fan-out for the runtime engine.

``Server`` runs the vmapped ClientUpdate for the whole cohort on one
device. Here the stacked client axis is instead partitioned across a
1-D ``("clients",)`` device mesh with ``shard_map``: each device vmaps
over its local shard of the cohort, no collectives needed (clients are
independent until aggregation, which stays in the engine). The cohort is
padded up to a multiple of the mesh size by repeating the last client
row, and the padded outputs are sliced off before judgment so verdicts
and aggregation see exactly |S_t| clients — both the pad and the slice
happen *inside* the one jitted program, so an uneven cohort pays no
per-round eager ``repeat``/``concatenate`` dispatches.

Cohort padding composes with the corpus's padded-shard layout
(:meth:`repro.data.corpus.ClientCorpus.shard`): the corpus pads the
*resident* client axis so an uneven N shards ``P("clients")``, while
this module pads the *gathered cohort* so an uneven |S_t| shard_maps —
two independent axes of the same uneven-mesh contract.

``make_client_mesh`` builds the 1-D mesh over whatever devices exist —
on a TPU slice that is the whole pod; reuse ``launch.mesh`` for 2-D
production meshes and pass ``mesh_axis_size`` devices explicitly.

The axis name is shared with :mod:`repro.data.corpus`: a ``ClientCorpus``
sharded over the same ``("clients",)`` mesh feeds its on-device cohort
gathers straight into this fan-out with no resharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ...core.strategies import ApplyFn
from ...data.corpus import CLIENT_AXIS
from ..server import _make_client_fn

__all__ = [
    "CLIENT_AXIS", "client_mesh_from", "make_client_mesh",
    "make_sharded_client_fn", "pad_to_multiple",
]


def make_client_mesh(devices=None) -> Mesh:
    """1-D mesh over ``devices`` (default: all) with a "clients" axis."""
    devs = list(devices) if devices is not None else jax.devices()
    return Mesh(np.asarray(devs), (CLIENT_AXIS,))


def client_mesh_from(mesh: Mesh) -> Mesh:
    """Client mesh over a production mesh's client rows.

    ``launch.mesh`` maps one FL client group per ("pod", "data") row
    (``fl_clients_for``); this takes the first device of each row — the
    weights-level ClientUpdate is small enough to live on one chip, the
    row's "model" axis stays free for model-parallel apply_fns."""
    from ...launch.mesh import fl_clients_for
    rows = fl_clients_for(mesh)
    devs = mesh.devices.reshape(rows, -1)[:, 0]
    return Mesh(devs, (CLIENT_AXIS,))


def pad_to_multiple(tree, multiple: int):
    """Edge-repeat every leaf's leading axis up to a multiple; identity if
    already divisible. Padded rows are dropped by the caller post-hoc, so
    repeating real rows keeps every traced op well-conditioned."""
    def pad(x):
        n = x.shape[0]
        rem = (-n) % multiple
        if rem == 0:
            return x
        reps = jnp.repeat(x[-1:], rem, axis=0)
        return jnp.concatenate([x, reps], axis=0)
    return jax.tree.map(pad, tree)


def make_sharded_client_fn(apply_fn: ApplyFn, spec, in_axes, mesh: Mesh,
                           *, donate_data: bool = True, inner=None,
                           inner_axes: tuple = (0,)):
    """shard_map'd + jitted ClientUpdate over the ("clients",) mesh axis.

    Returns ``fn(global_params, data, prev_p, c_loc, c_glob, ...)`` with
    the same signature/semantics as ``Server._client_fn()`` — including the
    leading-axis length of the result (padding is internal). ``in_axes``
    is the strategy's vmap spec; axis-0 arguments shard over the mesh,
    None arguments replicate.

    ``inner`` swaps the vmapped default for a strategy-built fn.
    ``inner_axes`` are the vmap axes of any arguments the inner fn takes
    *beyond* the standard five — the default ``(0,)`` is the FedCAT chain
    contract (one extra axis-0 chain-validity mask; the inner fn's
    leading axis is then the GROUP axis: whole chains shard onto devices,
    never individual chain stages, and mesh padding repeats whole groups
    whose (dropped) outputs cannot leak into real chains); strategies
    whose ``make_client_fn`` keeps the plain five-argument client
    signature (the LM window rule) pass ``()``.
    """
    vm = inner if inner is not None else _make_client_fn(apply_fn, spec,
                                                         in_axes)
    axes = tuple(in_axes) + (tuple(inner_axes) if inner is not None
                             else ())
    n = mesh.shape[CLIENT_AXIS]
    in_specs = tuple(P(CLIENT_AXIS) if ax == 0 else P() for ax in axes)
    mapped = shard_map(vm, mesh=mesh, in_specs=in_specs,
                       out_specs=P(CLIENT_AXIS), check_rep=False)

    def padded_call(global_params, data, *rest):
        # pad-to-mesh and slice-back are traced: shapes are static under
        # jit, so an uneven cohort costs zero eager dispatches per round
        # (the pad/slice fuse into the compiled program)
        m = jax.tree.leaves(data)[0].shape[0]
        args = (global_params, data) + rest
        padded = tuple(
            pad_to_multiple(a, n) if ax == 0 and a is not None else a
            for a, ax in zip(args, axes))
        out = mapped(*padded)
        if jax.tree.leaves(out)[0].shape[0] == m:
            return out
        return jax.tree.map(lambda x: x[:m], out)

    # the per-round data slices are fresh buffers — donating them lets XLA
    # reuse cohort-sized memory across pipelined rounds (no-op on CPU,
    # which cannot alias donated inputs and would warn every compile)
    donate_data = donate_data and jax.default_backend() != "cpu"
    return jax.jit(padded_call, donate_argnums=(1,) if donate_data else ())
