"""The pipelined, mesh-sharded round engine.

``PipelinedServer`` runs the exact Selector/ClientStrategy/Judge/Aggregator
composition of :class:`repro.fl.Server` with two independent levers:

**Sharding** (``RuntimeConfig.shard``): the stacked client axis of the
vmapped ClientUpdate is partitioned over a 1-D ``("clients",)`` device
mesh with ``shard_map`` (see :mod:`.sharding`), so |S_t| clients train on
``len(devices)`` chips instead of one. The ``ClientCorpus`` is laid out
over the same mesh exactly once (``corpus.shard``), so both the initial
dispatch and the speculative re-dispatch path gather their cohorts on
device from the resident corpus — the per-dispatch host slice + H2D
copy is gone. ``"auto"`` (default) shards only when more than one device
exists — on a single host device the engine compiles the identical
program a sequential ``Server`` would, which is what makes the
golden-history equivalence bit-for-bit.

**Speculation** (``RuntimeConfig.speculate``): paper Alg. 2 serializes
device compute behind the host-side float64 judgment oracle. The engine
breaks that chain by *speculating the verdict on device*: the traced
float32 judge (``core.judgment.judge``, ``spec_backend="xla"`` or
``"pallas"`` for the class-tiled kernel) produces a mask without leaving
the accelerator, aggregation and the next round's cohort compute dispatch
against it immediately (JAX async dispatch), and only then does the host
run the float64 oracle on the already-transferred soft labels. The two
judges provably agree except at float32 tie margins (tests/test_judgment),
so almost every round the oracle merely confirms the in-flight round t+1.
On a mismatch the speculated buffers are discarded and round t+1
re-dispatches from the oracle verdict — history records ``spec_hit`` per
round and ``redispatched`` on rounds whose compute was re-issued.

On the *streaming* data plane (:class:`repro.data.stream.HostCorpus`)
the same speculated selection doubles as the **prefetch target**: rather
than dispatching round t+1 eagerly (its host gather + H2D upload would
block the round loop), the engine hands the predicted cohort to the
corpus's background :class:`~repro.data.stream.CohortPrefetcher` and
dispatches only after the oracle confirms — the upload overlaps the
oracle's own device sync, and a misprediction cancels the staged buffers
with no wasted compute. Histories stay bit-for-bit: the dispatch runs
the identical programs on the identical inputs either side of the
oracle.

History and parameters are bit-for-bit identical to the sequential
``Server`` in BOTH modes: recorded verdicts/entropy always come from the
float64 oracle, the selector's RNG stream advances exactly as it would
sequentially (speculative draws happen on a throwaway deepcopy that is
adopted only when the verdict matches), and a confirmed speculative
aggregation is numerically the same float32 reduction the sequential path
runs.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...core.aggregation import comm_bytes
from ...core.judgment import judge as traced_judge
from ..judges import MaxEntropyJudge
from ..registry import register
from ..server import Server
from .sharding import (
    CLIENT_AXIS, client_mesh_from, make_client_mesh, make_sharded_client_fn,
)


@dataclass(frozen=True)
class RuntimeConfig:
    """Engine knobs; the defaults reproduce sequential ``Server`` behavior
    on one device and turn on mesh sharding automatically on many."""
    speculate: bool = False        # overlap oracle judgment with round t+1
    shard: object = "auto"         # True | False | "auto" (shard iff >1 dev)
    spec_backend: str = "xla"      # device judge for speculation: xla|pallas
    donate_data: bool = True       # donate per-round cohort data buffers


@register("engine", "sequential")
class SequentialEngine(Server):
    """Alias of :class:`repro.fl.Server` under the engine registry; accepts
    (and ignores) ``runtime=`` so ``build(..., engine=...)`` is uniform."""

    runtime_cls = RuntimeConfig   # build() rejects mismatched configs

    def __init__(self, *args, runtime: RuntimeConfig | None = None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self.runtime = runtime or RuntimeConfig()


@register("engine", "pipelined")
class PipelinedServer(Server):
    """Pipelined/sharded drop-in for ``Server`` (same composition axes)."""

    runtime_cls = RuntimeConfig   # build() rejects mismatched configs

    def __init__(self, *args, runtime: RuntimeConfig | None = None,
                 mesh=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.runtime = runtime or RuntimeConfig()
        if not isinstance(self.runtime, RuntimeConfig):
            # loud on direct construction too (build() catches it earlier):
            # an AsyncConfig here would half-work until .speculate access
            raise ValueError(
                f"{type(self).__name__} takes runtime=RuntimeConfig, got "
                f"{type(self.runtime).__name__}")
        self._mesh = mesh
        self._pending = None           # (sel, out) dispatched for round t+1
        self._redispatch_next = False  # previous speculation missed

    # ---------------------------------------------------------- sharding
    def _shard_enabled(self) -> bool:
        if self.runtime.shard == "auto":
            return len(jax.devices()) > 1
        return bool(self.runtime.shard)

    def client_mesh(self):
        """The 1-D ("clients",) mesh sharded rounds run on. A production
        ("pod", "data", "model") mesh passed at construction is reduced to
        its client rows (see :func:`.sharding.client_mesh_from`)."""
        if self._mesh is None:
            self._mesh = make_client_mesh()
        elif CLIENT_AXIS not in self._mesh.shape:
            self._mesh = client_mesh_from(self._mesh)
        return self._mesh

    def _client_fn(self):
        if not self._shard_enabled():
            return super()._client_fn()
        mesh = self.client_mesh()
        # the corpus is laid out over the client mesh exactly once
        # (idempotent): cohort gathers then run as SPMD programs over the
        # sharded operand and land distributed for the shard_map fan-out —
        # no per-dispatch host→device copy, no per-round resharding.
        # Uneven N pads to the next mesh multiple (P("clients") always,
        # never replicated), and the speculative re-dispatch path gathers
        # from the same padded-sharded operand. Must run before
        # _client_key(): the signature keys on the padded layout.
        self.corpus.shard(mesh)
        key = ("sharded",) + self._client_key() + (
            mesh.shape[CLIENT_AXIS], self.runtime.donate_data)
        make = getattr(self.strategy, "make_client_fn", None)
        return self._compile_cache().get(
            key, lambda: make_sharded_client_fn(
                self.apply_fn, self.strategy.spec,
                self._client_in_axes(), mesh,
                donate_data=self.runtime.donate_data,
                # chain strategies shard whole groups, not devices: the
                # inner fn's leading axis is the group axis and takes the
                # extra axis-0 validity mask; group-free custom clients
                # (lmstep) keep the plain five-argument signature
                inner=None if make is None else make(self.apply_fn),
                inner_axes=(0,) if getattr(
                    self.strategy, "prepare_round", None) is not None
                else ()))

    # -------------------------------------------------------- speculation
    def _traced_judge_fn(self):
        """Jitted on-device verdict for speculation; None disables it."""
        def make():
            # exact class (not subclasses, which may override traced()):
            # the runtime's spec_backend picks the device implementation
            if type(self.judge) is MaxEntropyJudge:
                backend = self.runtime.spec_backend

                def fn(s, z):
                    return traced_judge(s, z, backend=backend)
            else:
                traced = getattr(self.judge, "traced", None)
                if traced is None:
                    return None
                fn = traced()
            return jax.jit(fn)
        return self._compile_cache().get(
            ("spec-judge", self.judge, self.runtime.spec_backend), make)

    def _dispatch(self, sel, selector=None, global_params=None):
        """Launch a cohort's client compute (async). ``selector`` is whoever
        produced ``sel`` — under speculation a throwaway copy whose group
        assignment must ride with this dispatch (the group is the dispatch
        unit), never the server's own selector."""
        return self._run_cohort(
            sel, self.selector if selector is None else selector,
            global_params)

    # ------------------------------------------------------------- rounds
    def round(self) -> dict:
        if not self.runtime.speculate:
            return super().round()
        spec_fn = self._traced_judge_fn()
        if spec_fn is None:       # judge has no traced form: stay sequential
            return super().round()
        return self._speculative_round(spec_fn)

    def _speculative_round(self, spec_fn) -> dict:
        # drift applies BEFORE selection, exactly as sequentially. The
        # spec_next gate below guarantees no pending dispatch ever spans a
        # drift boundary, so the corpus swap never invalidates in-flight
        # compute (and never desyncs the adopted selector stream).
        drifted = self._apply_drift()
        if self.bank is not None:
            return self._clustered_spec_round(spec_fn, drifted)
        cfg = self.config
        num = cfg.cohort_size()

        if self._pending is not None:
            sel, out = self._pending
            self._pending = None
            redispatched = False
        else:
            sel = self.selector.select(num)
            out = self._dispatch(sel)
            redispatched = self._redispatch_next
        self._redispatch_next = False
        idx = np.asarray(sel)
        # round t+1 re-partitions some clients' data: dispatching it now
        # would train on the PRE-drift corpus. Keep round t's verdict
        # speculation (the aggregation overlap is still real) but skip the
        # next-round dispatch — t+1 selects synchronously after the swap.
        spec_next = not self._drift_at(self.round_idx + 1)

        # --- device-side speculative verdict + aggregation (all async) ---
        sizes32 = out["size"].astype(jnp.float32)
        jr = spec_fn(out["soft_label"].astype(jnp.float32), sizes32)
        new_global_spec = self.aggregator(self.global_params, out,
                                          sizes32, jr.mask)
        # state folding is mask-independent (Alg. 2): valid either way
        new_state = self.strategy.update_state(
            self.state, self.global_params, out, idx, cfg.num_clients)

        # --- speculatively select + dispatch round t+1 on a throwaway copy
        spec_mask = np.asarray(jr.mask)
        spec_pos = [sel[i] for i in range(len(sel)) if spec_mask[i] > 0]
        if jr.removal_order is not None:
            order = np.asarray(jr.removal_order)
            spec_neg = [sel[int(k)] for k in order if k >= 0]
        else:
            # order-less judges (e.g. budgeted): index order — pools are
            # set-based, so only the SET must match the oracle verdict
            spec_neg = [sel[i] for i in range(len(sel))
                        if spec_mask[i] == 0]
        # state folding is mask-independent (Alg. 2): adopt it before the
        # speculative dispatch, which slices its client inputs from it
        self.state = new_state
        prefetch = getattr(self.corpus, "prefetch", None)
        next_out = None
        if spec_next:
            sel_copy = copy.deepcopy(self.selector)
            sel_copy.update(spec_pos, spec_neg)
            next_sel = sel_copy.select(num)
            # group assignment rides with the dispatch: sel_copy made (and,
            # for chain strategies, grouped) this selection, so it is the
            # selector the cohort layout is read from
            if prefetch is None:
                next_out = self._dispatch(next_sel, sel_copy,
                                          new_global_spec)
            else:
                # streaming plane: a dispatch here would block THIS thread
                # on the host gather + H2D upload of round t+1's cohort.
                # Stage it on the prefetch thread instead, so the upload
                # overlaps the oracle's block on round t's soft labels
                # below; the dispatch itself waits for the verdict (on a
                # hit the gathered cohort is already staged — on a miss
                # nothing was computed against the wrong selection and only
                # the staged buffers are thrown away). The schedule read is
                # idempotent (`data_schedule` returns the counts fixed at
                # select time), so the dispatch's own read below sees
                # bit-identical counts.
                sched = getattr(sel_copy, "data_schedule", None)
                prefetch(np.asarray(next_sel),
                         None if sched is None else sched(next_sel))

        # --- float64 oracle on host, overlapping the in-flight compute ---
        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        a_rel, r_rel, ent = self.judge(soft, sizes)
        mask = np.zeros(len(sel), np.float32)
        mask[a_rel] = 1.0

        hit = bool(np.array_equal(mask, spec_mask))
        if hit:
            self.global_params = new_global_spec
            if spec_next:
                self.selector = sel_copy      # same verdict -> same stream
                if next_out is None:
                    # streaming plane: the cohort upload was prefetched
                    # above; this dispatch consumes the staged buffers (a
                    # hit in the prefetcher) instead of gathering
                    # synchronously
                    next_out = self._dispatch(next_sel, sel_copy,
                                              new_global_spec)
                self._pending = (next_sel, next_out)
            else:
                # drift boundary: no speculative t+1 exists; feed the
                # verdict back directly (identical to the sequential call)
                self.selector.update([sel[i] for i in a_rel],
                                     [sel[i] for i in r_rel])
        else:                                  # discard, redo from oracle
            if spec_next and prefetch is not None:
                # selector misprediction: drop the staged cohort — the
                # re-selected round t+1 falls back to a synchronous gather
                self.corpus.cancel_prefetch()
            self.global_params = self.aggregator(
                self.global_params, out,
                jnp.asarray(sizes, jnp.float32), jnp.asarray(mask))
            self.selector.update([sel[i] for i in a_rel],
                                 [sel[i] for i in r_rel])
            # a miss only forces a re-dispatch when a speculative t+1 was
            # actually issued (at a drift boundary nothing was in flight)
            self._redispatch_next = spec_next

        pos = [sel[i] for i in a_rel]
        neg = [sel[i] for i in r_rel]
        comm = comm_bytes(self.global_params, len(sel), len(pos),
                          soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": ent, "comm": comm,
               "spec_hit": hit, "redispatched": redispatched}
        self.history.append(rec)
        self.round_idx += 1
        return rec

    # ------------------------------------------------- clustered speculation
    def _clustered_spec_round(self, spec_fn, drifted) -> dict:
        """The speculative round over a K-center ModelBank.

        Structure mirrors ``_speculative_round`` with three deltas: the
        traced judge runs per cluster (masks combined over the cohort),
        the speculative aggregation is the ``perclstr`` masked mean over
        the bank, and the speculative NEXT assignment is computed against
        the speculatively aggregated bank — on an oracle hit that bank is
        bitwise the one the sequential path would have produced, so the
        assignment (host argmin over jitted scores) is bitwise too; on a
        miss the dispatch is discarded exactly like the unclustered path.
        Assignment-state folding (FeSEM) is verdict-independent by
        protocol contract and runs exactly once per round, before any
        speculative next-round assignment reads it.
        """
        cfg = self.config
        num = cfg.cohort_size()

        if self._pending is not None:
            sel, cids, out = self._pending
            self._pending = None
            redispatched = False
        else:
            sel = self.selector.select(num)
            cids = self.cluster.assign(sel)
            out = self._dispatch_banked(sel, self.selector, cids)
            redispatched = self._redispatch_next
        self._redispatch_next = False
        idx = np.asarray(sel)
        spec_next = not self._drift_at(self.round_idx + 1)

        # --- per-cluster device verdict (cluster-ascending, the oracle's
        # own order) combined into one cohort mask ---------------------
        sizes32 = out["size"].astype(jnp.float32)
        soft_dev = out["soft_label"].astype(jnp.float32)
        cids_np = np.asarray(cids)
        spec_mask = np.zeros(len(sel), np.float32)
        spec_pos, spec_neg = [], []
        for k in sorted(int(c) for c in np.unique(cids_np)):
            rows = np.where(cids_np == k)[0]
            jr = spec_fn(jnp.take(soft_dev, rows, axis=0),
                         jnp.take(sizes32, rows, axis=0))
            mk = np.asarray(jr.mask)
            spec_mask[rows[mk > 0]] = 1.0
            spec_pos.extend(sel[int(rows[i])] for i in range(len(rows))
                            if mk[i] > 0)
            if jr.removal_order is not None:
                order = np.asarray(jr.removal_order)
                spec_neg.extend(sel[int(rows[int(r)])] for r in order
                                if r >= 0)
            else:
                spec_neg.extend(sel[int(rows[i])] for i in range(len(rows))
                                if mk[i] == 0)

        out_c = dict(out)
        out_c["cluster"] = jnp.asarray(cids_np, jnp.int32)
        new_stacked_spec = self.aggregator(
            self.bank.stacked, out_c, sizes32, jnp.asarray(spec_mask))
        bank_spec = self.bank.replace(new_stacked_spec)
        new_state = self.strategy.update_state(
            self.state, self.bank.stacked, out, idx, cfg.num_clients)
        # once per round, against the PRE-aggregation centers, BEFORE the
        # speculative next assignment reads the sticky state it may mutate
        self.cluster.update(sel, cids_np, out, self.bank)

        # --- speculatively select + assign + dispatch round t+1 ---------
        self.state = new_state
        next_out = None
        if spec_next:
            sel_copy = copy.deepcopy(self.selector)
            sel_copy.update(spec_pos, spec_neg)
            next_sel = sel_copy.select(num)
            next_cids = self.cluster.assign(next_sel, bank=bank_spec)
            # clustered dispatch is always eager (no prefetch deferral):
            # the assignment itself must evaluate the cohort's data, so
            # the gather cannot be deferred behind the oracle anyway; on
            # the streaming plane this trades upload overlap for the
            # simpler invariant that a pending always holds real outputs
            next_out = self._dispatch_banked(next_sel, sel_copy, next_cids,
                                             bank=bank_spec)

        # --- float64 per-cluster oracle on host -------------------------
        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        mask, pos, neg, ent, clusters = self._judge_clusters(
            soft, sizes, cids_np, sel)

        hit = bool(np.array_equal(mask, spec_mask))
        if hit:
            self.bank = bank_spec
            if spec_next:
                self.selector = sel_copy      # same verdict -> same stream
                self._pending = (next_sel, next_cids, next_out)
            else:
                self.selector.update(pos, neg)
        else:                                  # discard, redo from oracle
            self.bank = self.bank.replace(self.aggregator(
                self.bank.stacked, out_c,
                jnp.asarray(sizes, jnp.float32), jnp.asarray(mask)))
            self.selector.update(pos, neg)
            self._redispatch_next = spec_next
        self.global_params = self.bank.stacked

        comm = comm_bytes(self.bank.center(0), len(sel), len(pos),
                          soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": ent, "comm": comm,
               "cluster": [int(c) for c in cids_np], "clusters": clusters,
               "spec_hit": hit, "redispatched": redispatched}
        if drifted:
            rec["drift"] = [list(ev.clients) for ev in drifted]
        self.history.append(rec)
        self.round_idx += 1
        return rec
