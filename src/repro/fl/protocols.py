"""Protocol classes for the four pluggable axes of an FL round (Alg. 2).

FedEntropy's judgment is a *composable add-on* (paper Sec. 3.4 / Table 3):
related methods swap exactly one axis of the round — who is asked
(``Selector``), how each client trains (``ClientStrategy``), whose update
is admitted (``Judge``), and how admitted updates merge (``Aggregator``).
These are ``typing.Protocol`` classes: any object with the right methods
plugs in, no inheritance required. Register implementations with
:func:`repro.fl.register` to name them in configs and benchmarks.

Data-plane vs control-plane split (the invariant every implementation must
keep): ``ClientStrategy``/``Aggregator`` run traced JAX on stacked client
axes; ``Selector``/``Judge`` run host-side numpy on per-round scalars.
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence, runtime_checkable

import jax
import numpy as np

Params = Any           # arbitrary pytree of arrays
StrategyState = Any    # pytree owned by a ClientStrategy (or None)


@runtime_checkable
class Selector(Protocol):
    """Chooses the round's device set S_t (Alg. 2 lines 4-8)."""

    def select(self, num: int) -> list[int]:
        """Draw ``num`` distinct device ids for this round."""
        ...

    def update(self, positives: Sequence[int],
               negatives: Sequence[int]) -> None:
        """Feed back the judgment verdict (Alg. 2 line 22)."""
        ...

    def stats(self) -> dict:
        """Introspection counters (pool sizes etc.) for logging."""
        ...


@runtime_checkable
class ClientStrategy(Protocol):
    """Owns the local-update rule and ALL of its cross-round state.

    State lives in an explicit pytree returned by :meth:`init_state` and
    threaded through :meth:`update_state` — never as ad-hoc attributes on
    the server. ``client_inputs``/``client_in_axes`` describe how the
    state is sliced onto the vmapped per-client update.
    """

    spec: Any                      # hyperparameters (LocalSpec)
    doubles_uplink: bool           # True if uplink carries control variates

    def init_state(self, global_params: Params,
                   num_clients: int) -> StrategyState:
        """Build the strategy's state pytree (None if stateless)."""
        ...

    def client_inputs(self, state: StrategyState, idx: np.ndarray
                      ) -> tuple[Params | None, Params | None, Params | None]:
        """Slice state for the selected clients: (prev_params, c_local,
        c_global) as consumed by ``core.strategies.client_update``."""
        ...

    def client_in_axes(self) -> tuple:
        """vmap in_axes for (global_params, data, prev_p, c_loc, c_glob)."""
        ...

    def update_state(self, state: StrategyState, global_params: Params,
                     out: dict, idx: np.ndarray,
                     num_clients: int) -> StrategyState:
        """Fold the round's client outputs back into the state pytree."""
        ...


@runtime_checkable
class Judge(Protocol):
    """Decides which selected devices' models aggregate (Alg. 1)."""

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        """Return (accepted, rejected, entropy) — positions are *relative*
        indices into the round's selection, entropy is the final group
        entropy over the accepted set (NaN if not entropy-based)."""
        ...


@runtime_checkable
class ClusterAssigner(Protocol):
    """Optional fifth axis: maps selected clients to model-bank centers.

    When a composition names a ``cluster`` assigner (and
    ``ServerConfig.num_clusters > 1``) the server carries a K-center
    :class:`repro.fl.clusters.ModelBank` instead of one pytree, clients
    train from their assigned center, and judgment + aggregation run per
    cluster. Control-plane contract: ``assign`` returns host-side numpy
    ids and must be *verdict-independent given the bank* (the pipelined
    engine assigns round t+1 against the speculatively aggregated bank
    and adopts it only on an oracle hit).
    """

    num_clusters: int

    def bind(self, server) -> None:
        """Attach the server whose corpus/bank/apply_fn drive assignment
        (mirrors ``Selector.bind_data``); called once at construction."""
        ...

    def assign(self, sel: Sequence[int], bank=None) -> np.ndarray:
        """Cluster id per selected client, drawn against ``bank`` (the
        server's current bank when ``None``)."""
        ...

    def update(self, sel: Sequence[int], cluster_ids: np.ndarray,
               out: dict, bank) -> None:
        """Fold the round's client outputs back into assignment state
        (FeSEM's sticky re-filing; a no-op for stateless assigners).
        Runs against the round's *pre-aggregation* bank."""
        ...

    def stats(self) -> dict:
        """Introspection counters (cluster occupancy etc.) for logging."""
        ...


@runtime_checkable
class Aggregator(Protocol):
    """Merges admitted client models into the next global model."""

    def __call__(self, global_params: Params, out: dict,
                 sizes: jax.Array, mask: jax.Array) -> Params:
        """``out`` is the stacked client-update dict (leading axis = |S_t|);
        ``mask`` is the judge's 0/1 admission mask over that axis."""
        ...
