"""The ``Server`` round driver: paper Alg. 2 with every axis pluggable.

One ``round()`` = select -> vmapped ClientUpdate -> judge -> aggregate ->
state/pool feedback. The data plane (client updates, aggregation) is
traced JAX over a stacked client axis; the control plane (selection,
judgment, pool bookkeeping) is host-side numpy — exactly the split the
legacy ``FedEntropyTrainer`` used, so fixed-seed round histories are
bit-for-bit reproducible.

Client data lives on a *data plane* (``data_plane=`` keyword, resolved by
:func:`repro.data.stream.as_data_plane`): device-resident
:class:`repro.data.corpus.ClientCorpus` by default (a plain stacked dict
is wrapped on construction), or the host-resident streaming
:class:`repro.data.stream.HostCorpus` when N doesn't fit. Either way the
per-round cohort reaches the device via ``corpus.cohort(idx)`` — a jitted
on-device gather (resident) or a host gather + single-cohort upload
(streaming) — the corpus keeps its storage dtype (uint8 ingest normalizes
inside the traced finish), and selectors draw their control-plane stats
(label histograms, sizes) off the corpus instead of recomputing them.
Selectors exposing ``data_schedule(sel)`` (the
dynamic-data-queue selector) have their per-client release counts
applied as a weight mask inside the same gather.

Compiled programs live in a per-server bounded LRU cache
(``ServerConfig.jit_cache_size``), not a module-global dict: a benchmark
sweep that builds hundreds of servers no longer accumulates params-sized
XLA executables for the lifetime of the process.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregation import comm_bytes
from ..core.strategies import ApplyFn, client_update, cross_entropy
from ..data.stream import as_data_plane, plane_of
from .protocols import Aggregator, ClientStrategy, Judge, Selector


@dataclass(frozen=True)
class ServerConfig:
    """Round-loop parameters (paper Sec. 4.1 defaults)."""
    num_clients: int = 100          # paper N
    participation: float = 0.1      # paper C
    eps: float = 0.8                # paper epsilon (eps-greedy selectors)
    seed: int = 0
    jit_cache_size: int = 4         # per-server compiled-program LRU bound
    group_size: int = 2             # FedCAT chain length (catgroups/catchain)
    num_clusters: int = 1           # K model-bank centers (1 = unclustered)

    def cohort_size(self) -> int:
        """|S_t| = max(1, round(N * C)) — the one place the paper's
        cohort sizing lives; every engine reads it here. Python's
        ``round`` is banker's (half-to-even): N=25, C=0.1 selects 2."""
        return max(1, int(round(self.num_clients * self.participation)))


class BoundedJitCache:
    """Tiny LRU for compiled programs, owned by one ``Server``.

    Thread-safe: the streaming data plane's cohort prefetcher runs on a
    background thread, so cache access is no longer guaranteed
    host-serial. ``make()`` runs *outside* the lock — a multi-second XLA
    compile must not stall other threads' lookups of unrelated keys —
    with per-key once semantics: concurrent callers of the same missing
    key dedupe onto one build (the others block on a per-key event and
    adopt the builder's entry).
    """

    def __init__(self, maxsize: int):
        self.maxsize = max(1, int(maxsize))
        self._entries: OrderedDict[Any, Any] = OrderedDict()
        self._building: dict[Any, threading.Event] = {}
        self._lock = threading.RLock()

    def _record(self, hit: bool) -> None:
        """Stats hook (called under the lock); subclasses count hits."""

    def get(self, key, make: Callable[[], Any]):
        while True:
            with self._lock:
                if key in self._entries:
                    self._entries.move_to_end(key)
                    self._record(True)
                    return self._entries[key]
                ev = self._building.get(key)
                if ev is None:
                    ev = self._building[key] = threading.Event()
                    break
            # another thread is compiling this key: wait, then re-probe
            # (if its build failed, or the entry was evicted before we
            # re-probed, we become the builder on the next pass)
            ev.wait()
        try:
            fn = make()
        except BaseException:
            with self._lock:
                self._building.pop(key, None)
            ev.set()
            raise
        with self._lock:
            self._entries[key] = fn
            self._record(False)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            self._building.pop(key, None)
        ev.set()
        return fn

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


def _make_client_fn(apply_fn: ApplyFn, spec, in_axes):
    """vmapped ClientUpdate with the strategy's state slices as extra args."""

    def one(global_params, data, prev_p, c_loc, c_glob):
        return client_update(
            apply_fn, global_params, data, spec,
            prev_params=prev_p, c_local=c_loc, c_global=c_glob)

    return jax.vmap(one, in_axes=in_axes)


class Server:
    """Host-side FL driver; compose with :func:`repro.fl.build` or directly::

        server = Server(apply_fn, params, data, ServerConfig(num_clients=32),
                        selector=PoolSelector(32), strategy=FedAvgStrategy(),
                        judge=MaxEntropyJudge(),
                        aggregator=WeightedAverageAggregator())
        server.fit(rounds=60, eval_every=5, eval_data=(xte, yte))
    """

    def __init__(
        self,
        apply_fn: ApplyFn,
        init_params,
        client_data: dict,          # x:(N,S,...), y:(N,S), w:(N,S)
        config: ServerConfig,
        *,
        selector: Selector,
        strategy: ClientStrategy,
        judge: Judge,
        aggregator: Aggregator,
        data_plane: str = "auto",
        cluster=None,
        drift=None,
    ):
        self.apply_fn = apply_fn
        self.global_params = init_params
        # the data plane: device-resident (fast path) or host-resident
        # streaming, per `data_plane` — an already-constructed corpus of
        # either plane passes through under "auto". Both planes are
        # Mappings, so `self.data` keeps its seed-era dict-like surface.
        self.corpus = as_data_plane(client_data, data_plane)
        self.data = self.corpus
        self.config = config
        self.selector = selector
        self.strategy = strategy
        self.judge = judge
        self.aggregator = aggregator
        self.state = strategy.init_state(init_params, config.num_clients)
        self.round_idx = 0
        self.history: list[dict] = []
        self._jit_cache = BoundedJitCache(config.jit_cache_size)
        # selectors that stat the corpus (CatGrouper's label histograms,
        # the queue selector's entropy ranking) bind it once here — the
        # corpus owns the cached control-plane stats
        bind = getattr(selector, "bind_data", None)
        if bind is not None:
            bind(self.corpus)
        # ---- the optional cluster axis (K-center ModelBank) ----------
        # K=1 (or no assigner) keeps bank=None: every code path below is
        # byte-identical to the single-model server, which is what makes
        # clustered compositions reduce to the seed goldens exactly.
        self.cluster = cluster
        k = (getattr(cluster, "num_clusters", 1)
             if cluster is not None else 1)
        if k > 1:
            if getattr(strategy, "make_client_fn", None) is not None or \
                    getattr(strategy, "prepare_round", None) is not None:
                raise ValueError(
                    f"{type(strategy).__name__} builds its own client "
                    "fan-out (chains/groups); the clustered ModelBank "
                    "needs the plain vmapped ClientUpdate to thread "
                    "per-client start params")
            if self.state is not None:
                raise ValueError(
                    f"{type(strategy).__name__} carries cross-round "
                    "client state; clustered rounds support stateless "
                    "strategies only (per-cluster control variates are a "
                    "recorded ROADMAP follow-up)")
            from .clusters import ModelBank
            self.bank = ModelBank.init(init_params, k, seed=config.seed)
            self.global_params = self.bank.stacked
        else:
            self.bank = None
        if cluster is not None:
            bindc = getattr(cluster, "bind", None)
            if bindc is not None:
                bindc(self)
        # ---- the optional drift schedule -----------------------------
        # events apply at the START of their round (before selection),
        # replacing the drifting clients' stacked rows and rebinding the
        # data plane + selector stats; see repro.data.partition.
        self._drift = sorted(list(drift or ()), key=lambda e: e.round)
        s = self.corpus.samples_per_client
        for ev in self._drift:
            got = {kk: np.shape(v)[1] for kk, v in ev.data.items()}
            if any(v != s for v in got.values()):
                raise ValueError(
                    f"drift event at round {ev.round} carries rows of "
                    f"sample length {got}, corpus has {s} "
                    "(regenerate with samples_per_client=corpus's)")

    # ------------------------------------------------------------------
    def _compile_cache(self):
        """Per-server LRU by default; the process-level cache when the
        runtime subsystem's opt-in is enabled (keys below carry the
        apply_fn identity so sharing across servers is sound)."""
        from .runtime.compile_cache import process_cache
        cache = process_cache()
        # explicit None check: an empty cache is len()==0, hence falsy
        return self._jit_cache if cache is None else cache

    def _client_key(self) -> tuple:
        # the apply_fn itself (identity hash) keys the entry — embedding
        # the object rather than id() pins it for the cache's lifetime,
        # so a GC'd callable can never alias a reused address. Strategies
        # that build their own client fn (chains) key on their class so a
        # vmapped program can never serve a chain cohort or vice versa.
        tag = ("client" if getattr(self.strategy, "make_client_fn", None)
               is None else f"client-{type(self.strategy).__name__}")
        return (tag, self.apply_fn, self.strategy.spec,
                self._client_in_axes(), self.corpus.signature())

    def _client_in_axes(self) -> tuple:
        """The strategy's vmap in_axes — with the params slot mapped
        (axis 0) on clustered servers: each cohort row then trains from
        its own bank center (``ModelBank.gather``'s (m, ...) stack)
        instead of one broadcast global model. Part of the compile-cache
        key, so banked and broadcast programs never alias."""
        ax = tuple(self.strategy.client_in_axes())
        return ((0,) + ax[1:]) if self.bank is not None else ax

    def _client_fn(self):
        make = getattr(self.strategy, "make_client_fn", None)
        if make is not None:
            return self._compile_cache().get(
                self._client_key(), lambda: jax.jit(make(self.apply_fn)))
        return self._compile_cache().get(
            self._client_key(), lambda: jax.jit(_make_client_fn(
                self.apply_fn, self.strategy.spec,
                self._client_in_axes())))

    def _eval_fn(self):
        fn = self.apply_fn
        return self._compile_cache().get(
            ("eval", fn), lambda: jax.jit(lambda p, bx: fn(p, bx)[0]))

    # ------------------------------------------------------------------
    def _run_cohort(self, sel, selector, global_params=None):
        """Gather, lay out, and launch the cohort's client compute (async).

        The cohort comes off the data plane — a jitted on-device gather
        along the resident corpus's client axis (only ``idx`` and a
        data-queue schedule, if the selector has one, cross the
        host→device boundary), or a host gather + cohort-sized upload on
        the streaming plane (which may consume a prefetched staging).
        Group-aware strategies
        (``prepare_round``) re-lay the gathered cohort into chain groups
        read off ``selector`` — the selector that produced ``sel``, which
        under speculation may be a throwaway copy: the group, not the
        device, is the dispatch unit, and its structure is captured at
        dispatch time.
        """
        gp = self.global_params if global_params is None else global_params
        idx = np.asarray(sel)
        sched = getattr(selector, "data_schedule", None)
        active = None if sched is None else sched(sel)
        data = self.corpus.cohort(idx, active=active)
        prev_p, c_loc, c_glob = self.strategy.client_inputs(self.state, idx)
        prep = getattr(self.strategy, "prepare_round", None)
        if prep is None:
            return self._client_fn()(gp, data, prev_p, c_loc, c_glob)
        gdata, aux = prep(data, selector)
        out = self._client_fn()(gp, gdata, prev_p, c_loc, c_glob,
                                aux["valid"])
        return self.strategy.finish_round(out, aux)

    # -------------------------------------------------------------- drift
    def _apply_drift(self) -> list:
        """Apply every drift event scheduled for the CURRENT round (before
        selection): replace the drifting clients' stacked rows, rebuild
        the corpus on its own plane, and rebind selector stats. Returns
        the applied events (history annotates drift rounds)."""
        applied = []
        while self._drift and self._drift[0].round == self.round_idx:
            ev = self._drift.pop(0)
            # as_numpy() may hand back read-only device views / memory
            # maps: copy only the arrays the event actually rewrites
            arrays = self.corpus.as_numpy()
            ids = np.asarray(ev.clients, np.int64)
            for key, rows in ev.data.items():
                if key in arrays:
                    arrays[key] = np.array(arrays[key])
                    arrays[key][ids] = np.asarray(
                        rows, arrays[key].dtype)
            transform = getattr(self.corpus, "transform", None)
            self.corpus = as_data_plane(arrays, plane_of(self.corpus),
                                        transform=transform)
            self.data = self.corpus
            bind = getattr(self.selector, "bind_data", None)
            if bind is not None:
                bind(self.corpus)
            applied.append(ev)
        return applied

    def _drift_at(self, round_no: int) -> bool:
        """True if a drift event is still scheduled for ``round_no`` —
        the pipelined engine must not speculate across that boundary."""
        return any(ev.round == round_no for ev in self._drift)

    # ---------------------------------------------------------- clustering
    def _dispatch_banked(self, sel, selector, cluster_ids, bank=None):
        """The clustered cohort dispatch: start params are each client's
        assigned center, gathered off ``bank`` (the server's own unless a
        speculative bank is passed)."""
        bank = self.bank if bank is None else bank
        return self._run_cohort(sel, selector, bank.gather(cluster_ids))

    def _judge_clusters(self, soft, sizes, cluster_ids, sel):
        """Per-cluster judgment: the composition's judge runs on each
        cluster's member rows independently (float64, host — the verdict
        of record for clustered rounds).

        Returns ``(mask, pos, neg, entropy, clusters)`` — the combined
        0/1 admission mask over the cohort, positive/negative client ids
        (clusters ascending, the judge's own order within each), the
        member-count-weighted mean of the per-cluster group entropies,
        and the per-cluster verdict dict the history records.
        """
        cluster_ids = np.asarray(cluster_ids)
        mask = np.zeros(len(sel), np.float32)
        pos, neg, clusters = [], [], {}
        ents = []
        for k in sorted(int(c) for c in np.unique(cluster_ids)):
            rows = np.where(cluster_ids == k)[0]
            a_rel, r_rel, ent = self.judge(soft[rows], sizes[rows])
            mask[rows[a_rel]] = 1.0
            p = [sel[int(rows[i])] for i in a_rel]
            n = [sel[int(rows[i])] for i in r_rel]
            pos.extend(p)
            neg.extend(n)
            clusters[str(k)] = {
                "members": [sel[int(i)] for i in rows],
                "positive": p, "negative": n, "entropy": ent}
            if not np.isnan(ent):
                ents.append((len(rows), ent))
        total = sum(n for n, _ in ents)
        entropy = (sum(n * e for n, e in ents) / total
                   if total else float("nan"))
        return mask, pos, neg, entropy, clusters

    def _clustered_round(self) -> dict:
        """One clustered Alg. 2 round: assign -> per-center ClientUpdate
        -> per-cluster judgment -> per-cluster aggregation -> feedback."""
        cfg = self.config
        sel = self.selector.select(cfg.cohort_size())
        idx = np.asarray(sel)
        cids = self.cluster.assign(sel)
        out = self._dispatch_banked(sel, self.selector, cids)

        soft = np.asarray(out["soft_label"], np.float64)
        sizes = np.asarray(out["size"], np.float64)
        mask, pos, neg, ent, clusters = self._judge_clusters(
            soft, sizes, cids, sel)

        out_c = dict(out)
        out_c["cluster"] = jnp.asarray(cids, jnp.int32)
        new_stacked = self.aggregator(
            self.bank.stacked, out_c,
            jnp.asarray(sizes, jnp.float32), jnp.asarray(mask))
        self.state = self.strategy.update_state(
            self.state, self.bank.stacked, out, idx, cfg.num_clients)
        # assignment state folds against the PRE-aggregation centers
        # (verdict-independent — the speculation contract)
        self.cluster.update(sel, cids, out, self.bank)
        self.bank = self.bank.replace(new_stacked)
        self.global_params = self.bank.stacked
        self.selector.update(pos, neg)

        # uplink accounting per the paper's model: positives ship ONE
        # model each (their own center), so the template is a single
        # center, never the K-stacked bank
        comm = comm_bytes(self.bank.center(0), len(sel), len(pos),
                          soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": ent, "comm": comm,
               "cluster": [int(c) for c in cids], "clusters": clusters}
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def round(self) -> dict:
        """One paper Alg. 2 round; returns the history record."""
        drifted = self._apply_drift()
        if self.bank is not None:
            rec = self._clustered_round()
            if drifted:
                rec["drift"] = [list(ev.clients) for ev in drifted]
            return rec
        cfg = self.config
        sel = self.selector.select(cfg.cohort_size())
        idx = np.asarray(sel)
        out = self._run_cohort(sel, self.selector)

        soft = np.asarray(out["soft_label"], np.float64)   # (|S_t|, C)
        sizes = np.asarray(out["size"], np.float64)

        a_rel, r_rel, ent = self.judge(soft, sizes)
        mask = np.zeros(len(sel), np.float32)
        mask[a_rel] = 1.0

        new_global = self.aggregator(
            self.global_params, out,
            jnp.asarray(sizes, jnp.float32), jnp.asarray(mask))
        self.state = self.strategy.update_state(
            self.state, self.global_params, out, idx, cfg.num_clients)
        self.global_params = new_global

        pos = [sel[i] for i in a_rel]
        neg = [sel[i] for i in r_rel]
        self.selector.update(pos, neg)

        comm = comm_bytes(self.global_params, len(sel), len(pos),
                          soft.shape[-1],
                          control_variate=self.strategy.doubles_uplink)
        rec = {"round": self.round_idx, "selected": sel, "positive": pos,
               "negative": neg, "entropy": ent, "comm": comm}
        self.history.append(rec)
        self.round_idx += 1
        return rec

    # ------------------------------------------------------------------
    def evaluate(self, x: jax.Array, y: jax.Array,
                 batch: int = 512, center: int | None = None) -> dict:
        """Test-set accuracy/loss. On a clustered server ``center`` picks
        the bank center to score (default 0 — the un-jittered lineage of
        the init params); unclustered servers ignore it."""
        n = x.shape[0]
        if n == 0:
            # loud, immediate: batch=min(batch,0)=0 would otherwise die in
            # range(0, 0, 0) before the correct/n ZeroDivisionError could
            raise ValueError("empty eval set (x has 0 rows)")
        params = self.global_params if self.bank is None \
            else self.bank.center(0 if center is None else int(center))
        batch = min(batch, n)
        correct, loss_sum = 0.0, 0.0
        f = self._eval_fn()
        for i in range(0, n, batch):
            bx, by = x[i:i + batch], y[i:i + batch]
            m = bx.shape[0]
            if m < batch:
                # edge-pad the tail batch to the full shape so every batch
                # runs the one compiled program (no n % batch variants);
                # padded rows are sliced off the logits before scoring
                reps = jnp.broadcast_to(bx[-1:], (batch - m,) + bx.shape[1:])
                bx = jnp.concatenate([bx, reps], axis=0)
            logits = f(params, bx)[:m]
            correct += float(jnp.sum(jnp.argmax(logits, -1) == by))
            loss_sum += float(cross_entropy(logits, by)) * m
        return {"accuracy": correct / n, "loss": loss_sum / n}

    def fit(self, rounds: int, eval_every: int = 0, eval_data=None) -> list:
        """Run ``rounds`` rounds; returns periodic eval metrics (if any)."""
        evals = []
        for r in range(rounds):
            self.round()
            if eval_every and eval_data is not None and \
                    (r + 1) % eval_every == 0:
                m = self.evaluate(*eval_data)
                m["round"] = self.round_idx
                evals.append(m)
        return evals


def total_uplink_bytes(history: list[dict]) -> int:
    return int(sum(h["comm"]["total_bytes"] for h in history))
