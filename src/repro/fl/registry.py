"""String registry for FL components and named compositions.

Benchmarks and configs name round compositions declaratively::

    server = repro.fl.build("fedentropy", apply_fn, params, data,
                            config=ServerConfig(num_clients=32))

Four component kinds (``selector``/``strategy``/``judge``/``aggregator``)
plus ``composition`` recipes that bundle one name per axis. Registering is
open to users::

    @repro.fl.register("judge", "topk")
    class TopKJudge: ...

    repro.fl.register("composition", "fedavg-topk",
                      Composition(selector="uniform", judge="topk"))

Built-in component classes expose ``from_config(config, local)``; entries
without it are constructed with no arguments (the common case for
user-defined judges). Passing an already-constructed instance to
:func:`build` bypasses the registry for that axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

KINDS = ("selector", "strategy", "judge", "aggregator", "cluster",
         "composition", "engine")

_REGISTRY: dict[str, dict[str, Any]] = {k: {} for k in KINDS}


@dataclass(frozen=True)
class Composition:
    """One component name per axis of the round. ``cluster`` (optional,
    a fifth axis) names a :mod:`repro.fl.clusters` assigner — the
    composition then runs a K-center ``ModelBank``
    (``ServerConfig.num_clusters``) with judgment and aggregation per
    cluster; ``None`` keeps the single-global-model round."""
    strategy: str = "fedavg"
    selector: str = "uniform"
    judge: str = "none"
    aggregator: str = "weighted"
    cluster: str | None = None


def register(kind: str, name: str, obj: Any = None):
    """Register ``obj`` under (kind, name); usable as a decorator."""
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")

    def _do(o):
        _REGISTRY[kind][name] = o
        return o

    return _do if obj is None else _do(obj)


def get(kind: str, name: str) -> Any:
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY.get(kind, ()))) or "<none>"
        raise KeyError(
            f"no {kind} registered under {name!r}; known: {known}") from None


def names(kind: str) -> list[str]:
    return sorted(_REGISTRY[kind])


def _instantiate(kind: str, spec: Any, config, local):
    """Resolve a component: instance pass-through, or name -> class -> obj."""
    if not isinstance(spec, str):
        return spec
    entry = get(kind, spec)
    if hasattr(entry, "from_config"):
        return entry.from_config(config=config, local=local)
    return entry()


def build(name: str, apply_fn, init_params, client_data, config,
          local=None, *, selector=None, strategy=None, judge=None,
          aggregator=None, cluster=None, engine=None, runtime=None,
          data_plane="auto", drift=None):
    """Construct a server (an *engine*) from a composition name.

    ``selector``/``strategy``/``judge``/``aggregator`` override individual
    axes of the named recipe — each accepts a registered name or a
    ready-made instance, so ablations are one-keyword swaps::

        build("fedentropy", ..., selector="uniform")   # Fig. 3b no-pools
        build("scaffold", ..., judge="maxent", selector="pools")  # Table 3

    ``engine`` picks the round driver (default the sequential
    :class:`repro.fl.Server`; ``"pipelined"`` is the mesh-sharded,
    speculation-capable :class:`repro.fl.runtime.PipelinedServer`;
    ``"async"`` is the streaming buffered
    :class:`repro.fl.runtime.AsyncBufferedServer`; ``"scan"`` is the
    R-rounds-per-program :class:`repro.fl.runtime.ScanServer`) and
    ``runtime`` passes that engine's config to it — a
    :class:`repro.fl.runtime.RuntimeConfig` for sequential/pipelined, an
    :class:`repro.fl.runtime.AsyncConfig` for async, a
    :class:`repro.fl.runtime.ScanConfig` for scan. A ``runtime`` without
    an ``engine`` implies the engine the config belongs to (RuntimeConfig
    → ``"pipelined"``, AsyncConfig → ``"async"``, ScanConfig →
    ``"scan"``); an unknown engine name raises ``ValueError`` listing the
    registered names, and an engine/runtime type mismatch errors here
    rather than deep in construction::

        build("fedentropy", ..., engine="pipelined",
              runtime=RuntimeConfig(speculate=True, spec_backend="pallas"))
        build("fedentropy", ..., engine="async",
              runtime=AsyncConfig(clock="straggler", staleness_alpha=0.5))

    ``data_plane`` picks where ``client_data`` lives
    (:func:`repro.data.stream.as_data_plane`): ``"resident"`` stacks it
    on device (:class:`repro.data.corpus.ClientCorpus`), ``"streaming"``
    keeps it host-side with per-cohort upload + speculative prefetch
    (:class:`repro.data.stream.HostCorpus`), ``"auto"`` (default) keeps
    the resident fast path while the corpus fits and passes constructed
    corpora through on their own plane.
    """
    from ..core.strategies import LocalSpec
    from . import runtime as _runtime  # registers engines
    from .server import Server

    comp = get("composition", name)
    local = local if local is not None else LocalSpec()
    strat = _instantiate("strategy", strategy or comp.strategy, config, local)
    if engine is None:
        # a runtime config without a named engine must not silently ignore
        # its knobs: route to the engine the config type belongs to
        if runtime is None:
            engine_cls = Server
        elif isinstance(runtime, _runtime.AsyncConfig):
            engine_cls = get("engine", "async")
        elif isinstance(runtime, _runtime.ScanConfig):
            engine_cls = get("engine", "scan")
        else:
            engine_cls = get("engine", "pipelined")
    elif isinstance(engine, str):
        try:
            engine_cls = get("engine", engine)
        except KeyError:
            raise ValueError(
                f"unknown engine {engine!r}; registered engines: "
                f"{', '.join(names('engine'))}") from None
    else:
        engine_cls = engine
    expected = getattr(engine_cls, "runtime_cls", None)
    if runtime is not None and expected is not None \
            and not isinstance(runtime, expected):
        raise ValueError(
            f"engine {engine_cls.__name__} takes runtime="
            f"{expected.__name__}, got {type(runtime).__name__} "
            "(RuntimeConfig drives sequential/pipelined, AsyncConfig "
            "drives async, ScanConfig drives scan)")
    kwargs = {}
    if runtime is not None:
        kwargs["runtime"] = runtime
    if data_plane != "auto":
        kwargs["data_plane"] = data_plane
    # the optional cluster axis: a named/instance ClusterAssigner makes
    # the engine carry a K-center ModelBank (K = config.num_clusters;
    # K=1 reduces to the single-model path exactly)
    cl = cluster if cluster is not None else comp.cluster
    if cl is not None:
        kwargs["cluster"] = _instantiate("cluster", cl, config, local)
    if drift is not None:
        kwargs["drift"] = drift
    return engine_cls(
        apply_fn, init_params, client_data, config,
        selector=_instantiate("selector", selector or comp.selector,
                              config, local),
        strategy=strat,
        judge=_instantiate("judge", judge or comp.judge, config, local),
        aggregator=_instantiate("aggregator", aggregator or comp.aggregator,
                                config, strat.spec),
        **kwargs,
    )


# ---- built-in composition recipes (paper Tables 1-3 / Fig. 3) -----------
register("composition", "fedentropy",
         Composition(strategy="fedavg", selector="pools", judge="maxent"))
# fedentropy with the pools driven by a jax.random stream instead of the
# numpy one: identical Alg. 2 semantics, but the draw is scan-foldable, so
# engine="scan" runs R>1 rounds per program (histories reproducible per
# seed, not golden-comparable with the numpy "pools" stream)
register("composition", "fedentropy-traced",
         Composition(strategy="fedavg", selector="pools-traced",
                     judge="maxent"))
register("composition", "fedavg", Composition(strategy="fedavg"))
register("composition", "fedprox", Composition(strategy="fedprox"))
register("composition", "moon", Composition(strategy="moon"))
register("composition", "scaffold",
         Composition(strategy="scaffold", aggregator="scaffold"))
# FedCAT (arXiv 2202.12751): entropy-grouped device chains, concatenation
# merge; "+maxent" filters chain membership with the paper's judgment
# before concatenation (the FedEntropy-synergy variant).
register("composition", "fedcat",
         Composition(strategy="catchain", selector="catgroups",
                     judge="none", aggregator="devconcat"))
register("composition", "fedcat+maxent",
         Composition(strategy="catchain", selector="catgroups-pools",
                     judge="maxent", aggregator="devconcat"))
# Dynamic-data-queue participant selection (arXiv 2410.17792): clients
# ranked by label entropy off the corpus stats, each round releasing a
# growing prefix of the local dataset; judgment stays the paper's maxent.
register("composition", "fedentropy+queue",
         Composition(strategy="fedavg", selector="queue", judge="maxent"))
# Clustered FL (the K-center ModelBank axis; K = ServerConfig.num_clusters):
# "ifca" is the loss-based assignment baseline (every update admitted),
# "fesem" the weight-distance alternation, and "ifca+maxent" runs the
# paper's max-entropy judgment WITHIN each cluster — at K=1 it is exactly
# the seed "fedentropy" recipe (perclstr degrades to weighted).
register("composition", "ifca",
         Composition(strategy="fedavg", selector="uniform", judge="none",
                     aggregator="perclstr", cluster="ifca"))
register("composition", "ifca+maxent",
         Composition(strategy="fedavg", selector="pools", judge="maxent",
                     aggregator="perclstr", cluster="ifca"))
register("composition", "fesem",
         Composition(strategy="fedavg", selector="uniform", judge="none",
                     aggregator="perclstr", cluster="fesem"))
