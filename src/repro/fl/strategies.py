"""ClientStrategy implementations wrapping ``core.strategies.client_update``.

Each class owns its cross-round state as an explicit pytree (returned by
``init_state``, threaded through ``update_state``) instead of ad-hoc
attributes on the trainer — the prerequisite for sharded/async execution
where strategy state must ship between hosts like any other array.

The local-update math itself stays in ``core.strategies.client_update``
(one vmappable function, paper Alg. 2 line 11); these classes only
describe how state is sliced onto and folded back from the stacked
per-client axis.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..core.strategies import LocalSpec, client_update
from .registry import register


class _StatelessStrategy:
    """Shared base for strategies with no cross-round state."""

    name = "fedavg"
    doubles_uplink = False

    def __init__(self, spec: LocalSpec | None = None):
        spec = spec or LocalSpec()
        # the class, not LocalSpec.strategy, picks the update rule now;
        # refuse a spec that explicitly names a *different* rule rather
        # than silently running the wrong method
        if spec.strategy not in (self.name, "fedavg"):
            raise ValueError(
                f"LocalSpec(strategy={spec.strategy!r}) conflicts with the "
                f"{self.name!r} strategy class; build the "
                f"{spec.strategy!r} composition instead (e.g. "
                f"build({spec.strategy!r}, ...)) or drop the field")
        self.spec = replace(spec, strategy=self.name)

    @classmethod
    def from_config(cls, config, local):
        return cls(local)

    def init_state(self, global_params, num_clients: int):
        return None

    def client_inputs(self, state, idx: np.ndarray):
        return None, None, None

    def client_in_axes(self) -> tuple:
        return (None, 0, None, None, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        return state


@register("strategy", "fedavg")
class FedAvgStrategy(_StatelessStrategy):
    """Plain local SGD(+momentum) [McMahan et al. 2017]."""
    name = "fedavg"


@register("strategy", "fedprox")
class FedProxStrategy(_StatelessStrategy):
    """FedAvg + proximal term to the global model [Li et al. 2020]."""
    name = "fedprox"


@register("strategy", "moon")
class MoonStrategy(_StatelessStrategy):
    """Model-contrastive learning [Li et al. 2021].

    State: ``prev_params`` — every client's last local model, stacked on a
    leading (num_clients,) axis.
    """
    name = "moon"

    def init_state(self, global_params, num_clients: int):
        return {"prev_params": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_clients,) + x.shape),
            global_params)}

    def client_inputs(self, state, idx: np.ndarray):
        prev = jax.tree.map(lambda x: x[idx], state["prev_params"])
        return prev, None, None

    def client_in_axes(self) -> tuple:
        return (None, 0, 0, None, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        return {"prev_params": jax.tree.map(
            lambda full, new: full.at[idx].set(new),
            state["prev_params"], out["params"])}


@register("strategy", "catchain")
class CatChainStrategy(_StatelessStrategy):
    """FedCAT device-concatenation chains (arXiv 2202.12751).

    The round's cohort is partitioned into the Selector's ordered groups
    (``last_groups``); within a group the devices train *sequentially* —
    each from its predecessor's output params, the first from the global
    model — expressed as a ``jax.lax.scan`` over the chain axis inside a
    ``vmap`` over groups, so the program stays jittable and shard_map
    partitions it over the group axis. The local rule is plain FedAvg SGD
    (the paper's); pair with ``DeviceConcatAggregator``.

    Ragged groups are padded to the longest chain by repeating the last
    member's data; padded stages carry ``valid=0`` and are select-masked to
    the identity inside the scan, so padding can never leak into a chain.
    Per-device outputs (the chain state after that device trained, its soft
    label and size) are returned in original cohort order with
    ``group_id``/``chain_pos`` annotations for the aggregator and judge.
    """

    name = "catchain"

    def __init__(self, spec: LocalSpec | None = None, group_size: int = 2):
        super().__init__(spec)
        self.group_size = max(1, int(group_size))

    @classmethod
    def from_config(cls, config, local):
        return cls(local, config.group_size)

    # ---- group layout (control-plane indices, data stays on device) -----
    def prepare_round(self, data: dict, selector) -> tuple[dict, dict]:
        """Lay the gathered cohort out as (G, K, S, ...) chain groups.

        ``data`` is the corpus's on-device cohort view; only the
        permutation/validity *indices* are computed host-side — the
        ragged-group relayout itself is a device gather/reshape.
        """
        n = data["x"].shape[0]
        groups = getattr(selector, "last_groups", None)
        if not groups:
            k = self.group_size
            groups = [list(range(i, min(i + k, n)))
                      for i in range(0, n, k)]
        k = max(len(g) for g in groups)
        perm = np.zeros((len(groups), k), np.int64)
        valid = np.zeros((len(groups), k), np.float32)
        gid = np.zeros(n, np.int32)
        pos = np.zeros(n, np.int32)
        inv = np.zeros(n, np.int64)
        for g, members in enumerate(groups):
            for j in range(k):
                perm[g, j] = members[min(j, len(members) - 1)]
                valid[g, j] = 1.0 if j < len(members) else 0.0
            for j, m in enumerate(members):
                gid[m], pos[m], inv[m] = g, j, g * k + j
        flat = perm.reshape(-1)
        gdata = {key: v[flat].reshape(perm.shape + v.shape[1:])
                 for key, v in data.items()}
        aux = {"valid": jnp.asarray(valid), "inv": inv,
               "group_id": jnp.asarray(gid), "chain_pos": jnp.asarray(pos)}
        return gdata, aux

    # ---- data plane ------------------------------------------------------
    def make_client_fn(self, apply_fn):
        spec = self.spec

        def chain_fn(global_params, gdata, prev_p, c_loc, c_glob, valid):
            del prev_p, c_loc, c_glob        # chains are stateless FedAvg

            def one_group(gd, gv):
                def stage(carry, inp):
                    d = {k: inp[k] for k in ("x", "y", "w")}
                    o = client_update(apply_fn, carry, d, spec)
                    newp = jax.tree.map(
                        lambda a, b: jnp.where(inp["_valid"] > 0, a, b),
                        o["params"], carry)
                    return newp, {"params": newp,
                                  "soft_label": o["soft_label"],
                                  "size": o["size"]}

                xs = dict(gd)
                xs["_valid"] = gv
                _, stages = jax.lax.scan(stage, global_params, xs)
                return stages

            return jax.vmap(one_group)(gdata, valid)

        return chain_fn

    def finish_round(self, out: dict, aux: dict) -> dict:
        """(G, K, ...) stage outputs -> (|S_t|, ...) in cohort order."""
        res = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:])[aux["inv"]], out)
        res["group_id"] = aux["group_id"]
        res["chain_pos"] = aux["chain_pos"]
        return res


@register("strategy", "lmstep")
class LMWindowStrategy(_StatelessStrategy):
    """Causal-LM local fine-tuning over full token windows.

    The classification strategies consume ``apply(params, x) -> (logits,
    feats)`` with one label per sample; the LM workload's natural unit is
    a token *window* — ``x`` is (S, L+1) int32 token ids, the model scores
    every next-token position at once (``apply(params, x) -> ((S, L, V)
    logits for targets x[:, 1:], feats)``), and there is no separate
    ``y``. This strategy is ``client_update`` re-derived for that
    contract: E epochs of minibatch SGD(+momentum) on the per-window
    mean next-token NLL (sample weights ``w`` mask padded windows
    exactly), identical loop structure to the classification rule —
    which is what keeps it stateless and therefore scan-foldable.

    Soft label (paper Eq. 2, LM analog): the weighted mean next-token
    softmax over every window *and* position,
    ``einsum("s,slv->v", w, probs) / (sum(w) * L)`` — a (V,)
    distribution the max-entropy judge consumes exactly like a
    num_classes-way soft label. ``size`` stays ``sum(w)`` (windows, the
    FedAvg weight), matching how the corpus pads client datasets.

    With ``epochs=1`` and ``batch_size >= S`` the parameter update is
    the ``examples`` trainer's single masked-gradient step
    (``make_train_step``) with momentum folded in.

    Note ``Server.evaluate`` assumes one-label-per-sample classification
    heads; LM runs read loss/perplexity off their own eval loop instead.
    """

    name = "lmstep"

    def make_client_fn(self, apply_fn):
        spec = self.spec

        def one(global_params, data, prev_p, c_loc, c_glob):
            del prev_p, c_loc, c_glob              # stateless
            x, w = data["x"], data["w"]
            s = x.shape[0]
            bs = min(spec.batch_size, s)
            nb = s // bs
            xb = x[: nb * bs].reshape((nb, bs) + x.shape[1:])
            wb = w[: nb * bs].reshape((nb, bs))

            def nll(p, bx, bw):
                logits, _ = apply_fn(p, bx)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                tgt = bx[:, 1:]
                tok = -jnp.take_along_axis(logp, tgt[..., None], -1)[..., 0]
                per_window = jnp.mean(tok, axis=-1)
                return (jnp.sum(per_window * bw)
                        / jnp.clip(jnp.sum(bw), 1e-12, None))

            grad_fn = jax.grad(nll)

            def sgd_step(carry, batch):
                p, mom = carry
                bx, bw = batch
                g = grad_fn(p, bx, bw)
                mom = jax.tree.map(lambda m, gi: spec.momentum * m + gi,
                                   mom, g)
                p = jax.tree.map(lambda pi, m: pi - spec.lr * m, p, mom)
                return (p, mom), None

            def epoch(carry, _):
                carry, _ = jax.lax.scan(sgd_step, carry, (xb, wb))
                return carry, None

            mom0 = jax.tree.map(jnp.zeros_like, global_params)
            (params, _), _ = jax.lax.scan(epoch, (global_params, mom0),
                                          None, length=spec.epochs)

            logits, _ = apply_fn(params, x)
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
            size = jnp.clip(jnp.sum(w), 1e-12, None)
            soft = (jnp.einsum("s,slv->v", w, probs)
                    / (size * probs.shape[1]))
            return {"params": params, "soft_label": soft,
                    "size": jnp.sum(w)}

        return jax.vmap(one, in_axes=(None, 0, None, None, None))


@register("strategy", "scaffold")
class ScaffoldStrategy(_StatelessStrategy):
    """Control-variate-corrected SGD [Karimireddy et al. 2020].

    State: server variate ``c_global`` plus per-client variates
    ``c_local`` stacked on a leading (num_clients,) axis. Pair with
    ``aggregator="scaffold"`` for the damped server step.
    """
    name = "scaffold"
    doubles_uplink = True           # uplink carries model + control variate

    def init_state(self, global_params, num_clients: int):
        return {
            "c_global": jax.tree.map(jnp.zeros_like, global_params),
            "c_local": jax.tree.map(
                lambda x: jnp.zeros((num_clients,) + x.shape, x.dtype),
                global_params),
        }

    def client_inputs(self, state, idx: np.ndarray):
        c_loc = jax.tree.map(lambda x: x[idx], state["c_local"])
        return None, c_loc, state["c_global"]

    def client_in_axes(self) -> tuple:
        return (None, 0, None, 0, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        # c <- c + |S_t|/N * mean_i dc_i ; c_i rows refreshed in place
        frac = len(idx) / num_clients
        dc = jax.tree.map(lambda d: jnp.mean(d, axis=0), out["c_delta"])
        return {
            "c_global": jax.tree.map(lambda c, d: c + frac * d,
                                     state["c_global"], dc),
            "c_local": jax.tree.map(lambda full, new: full.at[idx].set(new),
                                    state["c_local"], out["c_local"]),
        }
