"""ClientStrategy implementations wrapping ``core.strategies.client_update``.

Each class owns its cross-round state as an explicit pytree (returned by
``init_state``, threaded through ``update_state``) instead of ad-hoc
attributes on the trainer — the prerequisite for sharded/async execution
where strategy state must ship between hosts like any other array.

The local-update math itself stays in ``core.strategies.client_update``
(one vmappable function, paper Alg. 2 line 11); these classes only
describe how state is sliced onto and folded back from the stacked
per-client axis.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..core.strategies import LocalSpec
from .registry import register


class _StatelessStrategy:
    """Shared base for strategies with no cross-round state."""

    name = "fedavg"
    doubles_uplink = False

    def __init__(self, spec: LocalSpec | None = None):
        spec = spec or LocalSpec()
        # the class, not LocalSpec.strategy, picks the update rule now;
        # refuse a spec that explicitly names a *different* rule rather
        # than silently running the wrong method
        if spec.strategy not in (self.name, "fedavg"):
            raise ValueError(
                f"LocalSpec(strategy={spec.strategy!r}) conflicts with the "
                f"{self.name!r} strategy class; build the "
                f"{spec.strategy!r} composition instead (e.g. "
                f"build({spec.strategy!r}, ...)) or drop the field")
        self.spec = replace(spec, strategy=self.name)

    @classmethod
    def from_config(cls, config, local):
        return cls(local)

    def init_state(self, global_params, num_clients: int):
        return None

    def client_inputs(self, state, idx: np.ndarray):
        return None, None, None

    def client_in_axes(self) -> tuple:
        return (None, 0, None, None, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        return state


@register("strategy", "fedavg")
class FedAvgStrategy(_StatelessStrategy):
    """Plain local SGD(+momentum) [McMahan et al. 2017]."""
    name = "fedavg"


@register("strategy", "fedprox")
class FedProxStrategy(_StatelessStrategy):
    """FedAvg + proximal term to the global model [Li et al. 2020]."""
    name = "fedprox"


@register("strategy", "moon")
class MoonStrategy(_StatelessStrategy):
    """Model-contrastive learning [Li et al. 2021].

    State: ``prev_params`` — every client's last local model, stacked on a
    leading (num_clients,) axis.
    """
    name = "moon"

    def init_state(self, global_params, num_clients: int):
        return {"prev_params": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_clients,) + x.shape),
            global_params)}

    def client_inputs(self, state, idx: np.ndarray):
        prev = jax.tree.map(lambda x: x[idx], state["prev_params"])
        return prev, None, None

    def client_in_axes(self) -> tuple:
        return (None, 0, 0, None, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        return {"prev_params": jax.tree.map(
            lambda full, new: full.at[idx].set(new),
            state["prev_params"], out["params"])}


@register("strategy", "scaffold")
class ScaffoldStrategy(_StatelessStrategy):
    """Control-variate-corrected SGD [Karimireddy et al. 2020].

    State: server variate ``c_global`` plus per-client variates
    ``c_local`` stacked on a leading (num_clients,) axis. Pair with
    ``aggregator="scaffold"`` for the damped server step.
    """
    name = "scaffold"
    doubles_uplink = True           # uplink carries model + control variate

    def init_state(self, global_params, num_clients: int):
        return {
            "c_global": jax.tree.map(jnp.zeros_like, global_params),
            "c_local": jax.tree.map(
                lambda x: jnp.zeros((num_clients,) + x.shape, x.dtype),
                global_params),
        }

    def client_inputs(self, state, idx: np.ndarray):
        c_loc = jax.tree.map(lambda x: x[idx], state["c_local"])
        return None, c_loc, state["c_global"]

    def client_in_axes(self) -> tuple:
        return (None, 0, None, 0, None)

    def update_state(self, state, global_params, out, idx, num_clients):
        # c <- c + |S_t|/N * mean_i dc_i ; c_i rows refreshed in place
        frac = len(idx) / num_clients
        dc = jax.tree.map(lambda d: jnp.mean(d, axis=0), out["c_delta"])
        return {
            "c_global": jax.tree.map(lambda c, d: c + frac * d,
                                     state["c_global"], dc),
            "c_local": jax.tree.map(lambda full, new: full.at[idx].set(new),
                                    state["c_local"], out["c_local"]),
        }
