"""Aggregator implementations: merging admitted updates (Alg. 2 line 21).

``WeightedAverageAggregator`` — size-weighted FedAvg over the admitted
                                mask (``core.aggregation.aggregate``).
``FusedAverageAggregator``    — the same mean as ONE flat segment-reduce
                                (``core.aggregation.fused_aggregate``):
                                every leaf flattened into a single (M, P)
                                buffer, reduced in one kernel launch
                                (Pallas or xla) — float32-tolerance equal
                                to ``weighted``, not bitwise, so it is an
                                opt-in (``aggregator="fused"``) rather
                                than the golden-history default.
``ScaffoldAggregator``        — the same average, then the SCAFFOLD damped
                                server step w_g <- w_g + eta_g*(avg - w_g).
``DeviceConcatAggregator``    — FedCAT (arXiv 2202.12751): identity within
                                a chain, size-weighted average across the
                                chains' representative models.
``PerClusterAggregator``      — clustered FL: masks any base aggregator
                                over the K-center cluster axis (one
                                admitted-member average per center; empty
                                clusters keep their center unchanged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregation import aggregate, fused_aggregate
from .registry import register


@register("aggregator", "weighted")
class WeightedAverageAggregator:
    """w_g = sum_{i in A} L_i W_i / sum_{i in A} L_i."""

    @classmethod
    def from_config(cls, config, local):
        return cls()

    def __call__(self, global_params, out, sizes, mask):
        return aggregate(out["params"], sizes, mask)


@register("aggregator", "fused")
class FusedAverageAggregator:
    """``weighted``'s mean as one flat (M, P) segment-reduce.

    ``backend="pallas"`` tiles the flattened param axis through the VMEM
    kernel (``repro.kernels.fused_aggregate``); ``None``/"xla" uses the
    fused-jnp reference. One launch instead of one-per-leaf — the win
    grows with leaf count (LM pytrees; see benchmarks/roundscan.py).
    """

    def __init__(self, backend: str | None = None):
        self.backend = backend

    @classmethod
    def from_config(cls, config, local):
        return cls()

    def __call__(self, global_params, out, sizes, mask):
        return fused_aggregate(out["params"], sizes, mask,
                               backend=self.backend)


@register("aggregator", "scaffold")
class ScaffoldAggregator:
    """Weighted average followed by a global step of size ``lr_g``."""

    def __init__(self, lr_g: float = 1.0):
        self.lr_g = float(lr_g)

    @classmethod
    def from_config(cls, config, local):
        return cls(local.scaffold_lr_g)

    def __call__(self, global_params, out, sizes, mask):
        avg = aggregate(out["params"], sizes, mask)
        eta = self.lr_g
        return jax.tree.map(
            lambda wg, ag: wg + eta * (ag.astype(wg.dtype) - wg),
            global_params, avg)


@register("aggregator", "devconcat")
class DeviceConcatAggregator:
    """FedCAT merge: one model per chain, size-weighted across chains.

    ``out`` rows are per-device chain-stage outputs (device i's params are
    the chain state after i trained), annotated with ``group_id``/
    ``chain_pos`` by ``CatChainStrategy``. Within a chain the merge is the
    identity: the deepest stage whose admitted prefix is unbroken IS the
    group's model — it already contains its predecessors' training. Across
    chains those representatives average weighted by their admitted-prefix
    data sizes. Judgment therefore filters chain membership *before*
    concatenation: a rejected device truncates its chain at the last stage
    it never touched. A chain whose first device is rejected contributes
    nothing; if every chain is emptied the global model is kept unchanged.

    With group size 1 every device is its own chain and this reduces
    exactly (bit-for-bit) to ``WeightedAverageAggregator``. Cohorts
    without chain annotations degrade to the same plain weighted average.
    """

    @classmethod
    def from_config(cls, config, local):
        return cls()

    def __call__(self, global_params, out, sizes, mask):
        if "group_id" not in out:        # not a chain cohort: plain FedAvg
            return aggregate(out["params"], sizes, mask)
        gid, pos = out["group_id"], out["chain_pos"]
        m = jnp.asarray(mask, jnp.float32)
        same = gid[None, :] == gid[:, None]
        prefix = same & (pos[None, :] <= pos[:, None])
        # ok[i]: every chain stage up to and including i was admitted
        ok = jnp.all(jnp.where(prefix, m > 0, True), axis=1)
        # the deepest unbroken stage represents its chain
        deeper = same & (pos[None, :] > pos[:, None])
        rep = (ok & ~jnp.any(deeper & ok[None, :], axis=1)).astype(
            jnp.float32)
        # chain weight: total data size along the admitted prefix
        w = jnp.sum(jnp.where(prefix,
                              jnp.asarray(sizes, jnp.float32)[None, :],
                              0.0), axis=1)
        avg = aggregate(out["params"], w, rep)
        kept = jnp.sum(w * rep) > 0
        return jax.tree.map(
            lambda ag, wg: jnp.where(kept, ag, wg.astype(ag.dtype)),
            avg, global_params)


@register("aggregator", "perclstr")
class PerClusterAggregator:
    """Clustered merge: the base aggregator's weighted mean, masked over
    the cluster axis.

    On a clustered round ``global_params`` is the :class:`ModelBank`'s
    stacked (K, ...) pytree and ``out["cluster"]`` carries the round's
    per-client cluster ids; each center averages ONLY its own admitted
    members (``mask * (cluster == k)``) through the base aggregator, and
    a cluster with no admitted member this round keeps its center
    unchanged (the ``DeviceConcatAggregator`` empty-chain guard —
    ``masked_mean_tree``'s eps-clipped denominator would otherwise zero
    the center out).

    Unclustered cohorts (no ``"cluster"`` key — every K=1 round) pass
    straight through to the base aggregator, so ``ifca+maxent`` at K=1
    is bit-for-bit the ``weighted`` seed path.
    """

    def __init__(self, base=None):
        self.base = base if base is not None \
            else WeightedAverageAggregator()

    @classmethod
    def from_config(cls, config, local):
        return cls()

    def __call__(self, global_params, out, sizes, mask):
        if "cluster" not in out:
            return self.base(global_params, out, sizes, mask)
        cids = jnp.asarray(out["cluster"], jnp.int32)
        sizes = jnp.asarray(sizes, jnp.float32)
        mask = jnp.asarray(mask, jnp.float32)
        k = jax.tree.leaves(global_params)[0].shape[0]
        centers = []
        for c in range(k):
            member = (cids == c).astype(jnp.float32)
            mk = mask * member
            old = jax.tree.map(lambda s: s[c], global_params)
            avg = self.base(old, out, sizes, mk)
            kept = jnp.sum(sizes * mk) > 0
            centers.append(jax.tree.map(
                lambda a, o: jnp.where(kept, a.astype(o.dtype), o),
                avg, old))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *centers)
