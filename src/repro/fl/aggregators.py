"""Aggregator implementations: merging admitted updates (Alg. 2 line 21).

``WeightedAverageAggregator`` — size-weighted FedAvg over the admitted
                                mask (``core.aggregation.aggregate``).
``ScaffoldAggregator``        — the same average, then the SCAFFOLD damped
                                server step w_g <- w_g + eta_g*(avg - w_g).
"""
from __future__ import annotations

import jax

from ..core.aggregation import aggregate
from .registry import register


@register("aggregator", "weighted")
class WeightedAverageAggregator:
    """w_g = sum_{i in A} L_i W_i / sum_{i in A} L_i."""

    @classmethod
    def from_config(cls, config, local):
        return cls()

    def __call__(self, global_params, out, sizes, mask):
        return aggregate(out["params"], sizes, mask)


@register("aggregator", "scaffold")
class ScaffoldAggregator:
    """Weighted average followed by a global step of size ``lr_g``."""

    def __init__(self, lr_g: float = 1.0):
        self.lr_g = float(lr_g)

    @classmethod
    def from_config(cls, config, local):
        return cls(local.scaffold_lr_g)

    def __call__(self, global_params, out, sizes, mask):
        avg = aggregate(out["params"], sizes, mask)
        eta = self.lr_g
        return jax.tree.map(
            lambda wg, ag: wg + eta * (ag.astype(wg.dtype) - wg),
            global_params, avg)
