"""``repro.fl`` — the pluggable federated-learning server API.

Paper Alg. 2 decomposed into four independently swappable axes, each a
``typing.Protocol`` (see :mod:`repro.fl.protocols`):

=============  ====================================  ======================
axis           question it answers                   built-ins
=============  ====================================  ======================
``Selector``   who is asked to train this round      ``pools``, ``uniform``,
                                                     ``catgroups``,
                                                     ``catgroups-pools``,
                                                     ``queue``
``ClientStrategy``  how each client trains locally   ``fedavg``,
                                                     ``fedprox``,
                                                     ``scaffold``, ``moon``,
                                                     ``catchain``
``Judge``      whose update is admitted              ``maxent``, ``none``,
                                                     ``budget``
``Aggregator`` how admitted updates merge            ``weighted``,
                                                     ``scaffold``,
                                                     ``devconcat``
=============  ====================================  ======================

An optional fifth axis, ``cluster`` (:mod:`repro.fl.clusters`), swaps the
single global model for a K-center ``ModelBank``
(``ServerConfig.num_clusters``): clients train from their assigned
center (``ifca`` loss-based / ``fesem`` weight-distance assignment) and
judgment + aggregation run per cluster — compositions ``ifca``,
``ifca+maxent``, ``fesem``.

A further registry kind, ``engine``, picks the round *driver* for a
composition: ``"sequential"`` (the default ``Server``), ``"pipelined"``
(:mod:`repro.fl.runtime` — mesh-sharded client fan-out + judgment
speculation), or ``"async"`` (streaming buffered rounds: a deterministic
simulated arrival clock, per-arrival max-entropy admission, and
staleness-damped flushes), selected per-build via ``build(...,
engine=..., runtime=RuntimeConfig(...) | AsyncConfig(...))``.

Compositions are named in a registry so configs and benchmarks stay
declarative::

    import repro.fl as fl

    server = fl.build("fedentropy", apply_fn, params, client_data,
                      fl.ServerConfig(num_clients=32, participation=0.156))
    server.fit(rounds=60, eval_every=5, eval_data=(xte, yte))

Any axis is overridable per-build (``build("scaffold", ..., judge="maxent",
selector="pools")`` is paper Table 3's SCAFFOLD+FedEntropy), and new
components register under a string name::

    @fl.register("judge", "accept-all")
    class AcceptAll:
        def __call__(self, soft_labels, sizes):
            return list(range(len(sizes))), [], float("nan")

Migration from the legacy ``core.simulator`` trainer (still available as a
thin shim with identical fixed-seed round histories):

=====================================================  ====================
old (``FedEntropyTrainer`` + ``FLConfig``)             new (``repro.fl``)
=====================================================  ====================
``FLConfig(num_clients, participation, eps, seed)``    ``ServerConfig(...)``
``use_judgment=True, use_pools=True``                  ``build("fedentropy", ...)``
``use_judgment=False, use_pools=False``                ``build(<strategy>, ...)``
``use_judgment=True, use_pools=False`` (Fig. 3b)       ``build("fedentropy", ..., selector="uniform")``
``LocalSpec(strategy="scaffold", ...)``                ``build("scaffold", ..., local=LocalSpec(...))``
``trainer.round() / trainer.run(T)``                   ``server.round() / server.fit(T)``
``trainer.history``, ``trainer.evaluate(x, y)``        unchanged names on ``Server``
=====================================================  ====================
"""
from ..core.strategies import LocalSpec
from ..data.corpus import ClientCorpus, DataQueue, Normalize
from ..data.partition import DriftEvent, drift_schedule
from .aggregators import (
    DeviceConcatAggregator, PerClusterAggregator, ScaffoldAggregator,
    WeightedAverageAggregator,
)
from .clusters import (
    FeSEMAssigner, IFCAAssigner, ModelBank, argmin_assign,
)
from .judges import BudgetedJudge, MaxEntropyJudge, PassThroughJudge
from .protocols import (
    Aggregator, ClientStrategy, ClusterAssigner, Judge, Selector,
)
from .registry import Composition, build, get, names, register
from .selectors import (
    CatGrouper, PoolCatGrouper, PoolSelector, QueueSelector,
    TracedPoolSelector, UniformSelector,
)
from .server import (
    BoundedJitCache, Server, ServerConfig, total_uplink_bytes,
)
from .strategies import (
    CatChainStrategy, FedAvgStrategy, FedProxStrategy, LMWindowStrategy,
    MoonStrategy, ScaffoldStrategy,
)
from . import runtime  # noqa: E402 — registers engines; after .server
from .runtime import (
    AsyncBufferedServer, AsyncConfig, PipelinedServer, RuntimeConfig,
    ScanConfig, ScanServer,
)

__all__ = [
    "Aggregator", "AsyncBufferedServer", "AsyncConfig", "BoundedJitCache",
    "BudgetedJudge", "CatChainStrategy", "CatGrouper", "ClientCorpus",
    "ClientStrategy", "ClusterAssigner", "Composition", "DataQueue",
    "DeviceConcatAggregator", "DriftEvent", "FeSEMAssigner",
    "FedAvgStrategy", "FedProxStrategy", "IFCAAssigner", "Judge",
    "LMWindowStrategy", "LocalSpec", "MaxEntropyJudge", "ModelBank",
    "MoonStrategy", "Normalize", "PassThroughJudge", "PerClusterAggregator",
    "PipelinedServer", "PoolCatGrouper", "PoolSelector", "QueueSelector",
    "RuntimeConfig", "ScaffoldAggregator", "ScaffoldStrategy", "ScanConfig",
    "ScanServer", "Selector", "Server", "ServerConfig",
    "TracedPoolSelector", "UniformSelector", "WeightedAverageAggregator",
    "argmin_assign", "build", "drift_schedule", "get", "names", "register",
    "runtime", "total_uplink_bytes",
]
