"""Clustered federated learning: the K-center ``ModelBank`` axis.

FedEntropy screens local models against ONE global model; clustered FL
(FedGroup, arXiv 2010.06870; IFCA; FeSEM) attacks the same non-IID bias
with several concurrent group models. This module adds that axis to the
registry without forking the engines:

* :class:`ModelBank` — a stacked K-center param pytree (leading cluster
  axis). Center 0 is exactly the init params; centers 1..K-1 are
  deterministic jittered copies (seeded ``jax.random``), so K=1 IS the
  single-model seed path bit-for-bit.
* :class:`IFCAAssigner` (registry ``cluster="ifca"``) — loss-based
  assignment: one vmapped evaluation of every center on every selected
  client's local data (a (K, m) loss matrix in one jitted program), host
  ``argmin`` per client (float64 cast, lowest-index ties — deterministic
  across engines).
* :class:`FeSEMAssigner` (registry ``cluster="fesem"``) — weight-distance
  alternation: sticky per-client assignments (seeded init), re-assigned
  *after* each round by ``argmin_k ||w_i - c_k||^2`` against the
  pre-aggregation centers. Assignment is verdict-independent, which is
  what lets the pipelined engine speculate through it.

Judgment and aggregation run *within* each cluster: the server masks the
round's verdict per cluster (``Server._judge_clusters``) and the
``perclstr`` aggregator (:mod:`repro.fl.aggregators`) averages each
center over its admitted members only, keeping empty clusters' centers
unchanged. Compositions: ``ifca``, ``ifca+maxent`` (per-cluster
max-entropy judgment — the composition no baseline has), ``fesem``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .registry import register


@dataclass(frozen=True)
class ModelBank:
    """K stacked model centers: every leaf carries a leading cluster
    axis. Thin and immutable — engines swap whole banks per round."""
    stacked: Any          # pytree, leading axis K on every leaf
    k: int

    @classmethod
    def init(cls, params, k: int, *, seed: int = 0,
             jitter: float = 1e-2) -> "ModelBank":
        """Center 0 is ``params`` EXACTLY (the K=1 reduction); centers
        1..K-1 add seeded gaussian jitter (scale ``jitter``) so the
        loss-based assignment has distinct centers to separate."""
        if k < 1:
            raise ValueError("ModelBank needs k >= 1 centers")
        leaves, treedef = jax.tree.flatten(params)
        base = jax.random.PRNGKey(np.uint32(seed))
        centers = [leaves]
        for c in range(1, k):
            kc = jax.random.fold_in(base, c)
            jittered = []
            for i, leaf in enumerate(leaves):
                if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
                    noise = jax.random.normal(
                        jax.random.fold_in(kc, i), jnp.shape(leaf),
                        jnp.asarray(leaf).dtype)
                    jittered.append(leaf + jitter * noise)
                else:
                    jittered.append(leaf)
            centers.append(jittered)
        stacked = [jnp.stack([c[i] for c in centers])
                   for i in range(len(leaves))]
        return cls(stacked=jax.tree.unflatten(treedef, stacked), k=int(k))

    def replace(self, stacked) -> "ModelBank":
        return ModelBank(stacked=stacked, k=self.k)

    def center(self, i: int):
        """Center ``i`` as a plain (unstacked) param pytree."""
        return jax.tree.map(lambda s: s[i], self.stacked)

    def gather(self, cluster_ids):
        """Per-client start params: row ``j`` is the center assigned to
        client ``j`` — the (m, ...) stacked tree the banked client fan-out
        vmaps/shards over (in_axes 0 on the params slot)."""
        cids = jnp.asarray(np.asarray(cluster_ids), jnp.int32)
        return jax.tree.map(lambda s: jnp.take(s, cids, axis=0),
                            self.stacked)


def argmin_assign(scores) -> np.ndarray:
    """Host-deterministic per-client assignment from a (K, m) score
    matrix: float64 cast, ``argmin`` over the center axis, lowest index
    on ties — the one place both assigners' verdicts are decided, so the
    tie-break is engine-independent by construction."""
    scores = np.asarray(scores, np.float64)
    if scores.ndim != 2:
        raise ValueError(f"scores must be (K, m), got {scores.shape}")
    return np.argmin(scores, axis=0).astype(np.int64)


@register("cluster", "ifca")
class IFCAAssigner:
    """IFCA-style loss-based assignment (cluster id = argmin-loss center).

    ``bind(server)`` once at construction; ``assign(sel)`` evaluates the
    weighted cross-entropy of every center on every selected client's
    local data in one jitted ``vmap(K) x vmap(m)`` program, then picks
    per-client argmin on host. Assignment is recomputed every round from
    the current bank (``bank=`` overrides it — the pipelined engine
    assigns round t+1 against the speculatively aggregated bank).
    """

    def __init__(self, num_clusters: int):
        if num_clusters < 1:
            raise ValueError("num_clusters must be >= 1")
        self.num_clusters = int(num_clusters)
        self._server = None
        self.assign_rounds = 0

    @classmethod
    def from_config(cls, config, local):
        return cls(getattr(config, "num_clusters", 1))

    def bind(self, server) -> None:
        self._server = server

    def _loss_fn(self):
        srv = self._server
        apply_fn = srv.apply_fn

        def losses(stacked, data):
            def one_center(center):
                def one_client(x, y, w):
                    logits = apply_fn(center, x)[0].astype(jnp.float32)
                    logp = jax.nn.log_softmax(logits, axis=-1)
                    nll = -jnp.take_along_axis(
                        logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
                    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
                return jax.vmap(one_client)(data["x"], data["y"], data["w"])
            return jax.vmap(one_center)(stacked)       # (K, m)

        return srv._compile_cache().get(
            ("ifca-assign", apply_fn, srv.corpus.signature()),
            lambda: jax.jit(losses))

    def assign(self, sel, bank: ModelBank | None = None) -> np.ndarray:
        srv = self._server
        bank = srv.bank if bank is None else bank
        data = srv.corpus.cohort(np.asarray(sel))
        scores = self._loss_fn()(bank.stacked, data)
        self.assign_rounds += 1
        return argmin_assign(scores)

    def update(self, sel, cluster_ids, out, bank) -> None:
        """IFCA re-assigns from scratch each round; nothing to fold."""

    def stats(self) -> dict:
        return {"kind": "ifca", "num_clusters": self.num_clusters,
                "assign_rounds": self.assign_rounds}


@register("cluster", "fesem")
class FeSEMAssigner:
    """FeSEM-style weight-distance assignment with sticky memberships.

    Every client holds a persistent cluster id (seeded uniform init over
    the K centers); ``assign(sel)`` just reads it. After each round
    ``update`` re-files the participating clients by squared weight
    distance between their trained local params and the round's
    *pre-aggregation* centers — the alternating-optimization step, and
    verdict-independent, so speculation replays it exactly.
    """

    def __init__(self, num_clusters: int, num_clients: int, seed: int = 0):
        if num_clusters < 1:
            raise ValueError("num_clusters must be >= 1")
        self.num_clusters = int(num_clusters)
        self.num_clients = int(num_clients)
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), 0xFE5E]))
        self.assignments = (
            np.zeros(self.num_clients, np.int64) if self.num_clusters == 1
            else rng.integers(0, self.num_clusters, size=self.num_clients,
                              dtype=np.int64))
        self._server = None
        self.reassigned = 0

    @classmethod
    def from_config(cls, config, local):
        return cls(getattr(config, "num_clusters", 1),
                   config.num_clients, config.seed)

    def bind(self, server) -> None:
        self._server = server

    def _dist_fn(self):
        srv = self._server

        def dists(stacked, rows):
            def one_center(center):
                per_leaf = jax.tree.map(
                    lambda r, c: jnp.sum(
                        jnp.square(r.astype(jnp.float32)
                                   - c[None].astype(jnp.float32)),
                        axis=tuple(range(1, r.ndim))),
                    rows, center)
                return sum(jax.tree.leaves(per_leaf))   # (m,)
            return jax.vmap(one_center)(stacked)        # (K, m)

        return srv._compile_cache().get(
            ("fesem-dist", srv.apply_fn), lambda: jax.jit(dists))

    def assign(self, sel, bank: ModelBank | None = None) -> np.ndarray:
        return self.assignments[np.asarray(sel, np.int64)].copy()

    def update(self, sel, cluster_ids, out, bank: ModelBank) -> None:
        scores = self._dist_fn()(bank.stacked, out["params"])
        new = argmin_assign(scores)
        idx = np.asarray(sel, np.int64)
        self.reassigned += int(np.sum(self.assignments[idx] != new))
        self.assignments[idx] = new

    def stats(self) -> dict:
        counts = np.bincount(self.assignments,
                             minlength=self.num_clusters)
        return {"kind": "fesem", "num_clusters": self.num_clusters,
                "reassigned": self.reassigned,
                "cluster_counts": [int(c) for c in counts]}
