"""Judge implementations: which selected devices' models aggregate.

``MaxEntropyJudge``   — the paper's Algorithm 1 (greedy removal maximising
                        size-weighted group entropy) via
                        ``core.judgment.judge_np``, the float64 oracle the
                        legacy trainer used.
``PassThroughJudge``  — admits everyone (the ``use_judgment=False``
                        ablation / plain FedAvg-of-selected).
``BudgetedJudge``     — beyond-paper forward-greedy selection of exactly
                        ``budget`` devices (``core.judgment.judge_budgeted``)
                        for deployments with a hard per-round uplink cap.

All return ``(accepted, rejected, entropy)`` with *relative* indices into
the round's selection (see ``protocols.Judge``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.judgment import judge_budgeted, judge_np
from .registry import register


@register("judge", "maxent")
class MaxEntropyJudge:
    """Paper Algorithm 1: drop devices whose removal raises group entropy."""

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        return judge_np(soft_labels, sizes)


@register("judge", "none")
class PassThroughJudge:
    """Admit every selected device; entropy is not defined (NaN)."""

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        return list(range(len(sizes))), [], float("nan")


@register("judge", "budget")
class BudgetedJudge:
    """Keep exactly ``budget`` devices, forward-greedy on group entropy."""

    def __init__(self, budget: int):
        self.budget = int(budget)

    @classmethod
    def from_config(cls, config, local):
        raise ValueError(
            "BudgetedJudge needs an explicit budget — pass an instance, "
            "e.g. build(..., judge=BudgetedJudge(budget=3))")

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        res = judge_budgeted(jnp.asarray(soft_labels, jnp.float32),
                             jnp.asarray(sizes, jnp.float32), self.budget)
        mask = np.asarray(res.mask)
        accepted = [i for i in range(len(mask)) if mask[i] > 0]
        rejected = [i for i in range(len(mask)) if mask[i] == 0]
        return accepted, rejected, float(res.entropy)
