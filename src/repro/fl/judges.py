"""Judge implementations: which selected devices' models aggregate.

``MaxEntropyJudge``   — the paper's Algorithm 1 (greedy removal maximising
                        size-weighted group entropy). ``backend=`` picks the
                        implementation: ``"numpy"`` (default) is the float64
                        oracle the legacy trainer used; ``"xla"`` and
                        ``"pallas"`` route through the traced
                        ``core.judgment.judge`` — the latter tiles the class
                        axis through the Pallas ``entropy_judge_sweep``
                        kernel for huge C.
``PassThroughJudge``  — admits everyone (the ``use_judgment=False``
                        ablation / plain FedAvg-of-selected).
``BudgetedJudge``     — beyond-paper forward-greedy selection of exactly
                        ``budget`` devices (``core.judgment.judge_budgeted``)
                        for deployments with a hard per-round uplink cap.

All return ``(accepted, rejected, entropy)`` with *relative* indices into
the round's selection (see ``protocols.Judge``); rejected indices are in
greedy-removal order for every backend. Judges additionally expose
``traced()`` — a jit-compatible callable returning a
``core.judgment.JudgmentResult`` — which is how the mesh train step
(``repro.launch.train``) and the pipelined engine's speculation
(``repro.fl.runtime``) run the same judge axis on device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.judgment import (
    JudgmentResult, judge, judge_budgeted, judge_np,
)
from .registry import register


def _result_to_lists(res: JudgmentResult
                     ) -> tuple[list[int], list[int], float]:
    mask = np.asarray(res.mask)
    accepted = [i for i in range(len(mask)) if mask[i] > 0]
    if res.removal_order is not None:
        rejected = [int(k) for k in np.asarray(res.removal_order) if k >= 0]
    else:
        rejected = [i for i in range(len(mask)) if mask[i] == 0]
    return accepted, rejected, float(res.entropy)


@register("judge", "maxent")
class MaxEntropyJudge:
    """Paper Algorithm 1: drop devices whose removal raises group entropy.

    backend: "numpy" (float64 host oracle), "xla" (traced float32
    leave-one-out sweep) or "pallas" (class-axis-tiled kernel).
    """

    def __init__(self, backend: str = "numpy"):
        if backend not in ("numpy", "xla", "pallas"):
            raise ValueError(f"unknown judge backend {backend!r}")
        self.backend = backend
        self._jitted = None      # compiled host-call path, built lazily

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        if self.backend == "numpy":
            return judge_np(soft_labels, sizes)
        if self._jitted is None:  # don't re-trace the while_loop per round
            self._jitted = jax.jit(self.traced())
        res = self._jitted(jnp.asarray(soft_labels, jnp.float32),
                           jnp.asarray(sizes, jnp.float32))
        return _result_to_lists(res)

    def traced(self):
        """Jit-compatible (soft_labels, sizes) -> JudgmentResult; numpy
        backend falls back to the xla sweep (same greedy, float32)."""
        backend = "xla" if self.backend == "numpy" else self.backend
        return lambda soft, sizes: judge(soft, sizes, backend=backend)


@register("judge", "none")
class PassThroughJudge:
    """Admit every selected device; entropy is not defined (NaN)."""

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        return list(range(len(sizes))), [], float("nan")

    def traced(self):
        def all_in(soft, sizes):
            m = soft.shape[0]
            ones = jnp.ones((m,), jnp.float32)
            nan = jnp.full((), jnp.nan, jnp.float32)
            return JudgmentResult(
                mask=ones, entropy=nan, initial_entropy=nan,
                num_removed=jnp.zeros((), jnp.int32),
                removal_order=jnp.full((m,), -1, jnp.int32))
        return all_in


@register("judge", "budget")
class BudgetedJudge:
    """Keep exactly ``budget`` devices, forward-greedy on group entropy."""

    def __init__(self, budget: int):
        self.budget = int(budget)

    @classmethod
    def from_config(cls, config, local):
        raise ValueError(
            "BudgetedJudge needs an explicit budget — pass an instance, "
            "e.g. build(..., judge=BudgetedJudge(budget=3))")

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        res = judge_budgeted(jnp.asarray(soft_labels, jnp.float32),
                             jnp.asarray(sizes, jnp.float32), self.budget)
        return _result_to_lists(res)

    def traced(self):
        budget = self.budget
        return lambda soft, sizes: judge_budgeted(soft, sizes, budget)
