"""Judge implementations: which selected devices' models aggregate.

``MaxEntropyJudge``   — the paper's Algorithm 1 (greedy removal maximising
                        size-weighted group entropy). ``backend=`` picks the
                        implementation: ``"numpy"`` (default) is the float64
                        oracle the legacy trainer used; ``"xla"`` and
                        ``"pallas"`` route through the traced
                        ``core.judgment.judge`` — the latter tiles the class
                        axis through the Pallas ``entropy_judge_sweep``
                        kernel for huge C.
``PassThroughJudge``  — admits everyone (the ``use_judgment=False``
                        ablation / plain FedAvg-of-selected).
``BudgetedJudge``     — beyond-paper forward-greedy selection of exactly
                        ``budget`` devices (``core.judgment.judge_budgeted``)
                        for deployments with a hard per-round uplink cap.

All return ``(accepted, rejected, entropy)`` with *relative* indices into
the round's selection (see ``protocols.Judge``); rejected indices are in
greedy-removal order for every backend. Judges additionally expose
``traced()`` — a jit-compatible callable returning a
``core.judgment.JudgmentResult`` — which is how the mesh train step
(``repro.launch.train``) and the pipelined engine's speculation
(``repro.fl.runtime``) run the same judge axis on device.

The async buffered engine screens *arriving* updates instead of whole
rounds: ``MaxEntropyJudge.admit`` judges candidates against the
already-admitted (protected) buffer, and :func:`admit_candidates` adapts
any plain round judge to the same candidate-relative admission signature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.judgment import (
    JudgmentResult, judge, judge_budgeted, judge_np,
)
from .registry import register


def _result_to_lists(res: JudgmentResult
                     ) -> tuple[list[int], list[int], float]:
    mask = np.asarray(res.mask)
    accepted = [i for i in range(len(mask)) if mask[i] > 0]
    if res.removal_order is not None:
        rejected = [int(k) for k in np.asarray(res.removal_order) if k >= 0]
    else:
        rejected = [i for i in range(len(mask)) if mask[i] == 0]
    return accepted, rejected, float(res.entropy)


def _stack_buffer(buffer_soft, buffer_sizes, cand_soft, cand_sizes):
    """Concatenate (buffer, candidates) as float64; nb==0 passes the
    candidate arrays through untouched so admission over an empty buffer
    is bit-for-bit the plain round judgment (the async engine's reduction
    guarantee rides on this)."""
    cand_soft = np.asarray(cand_soft, np.float64)
    cand_sizes = np.asarray(cand_sizes, np.float64)
    nb = int(np.shape(buffer_sizes)[0])
    if nb == 0:
        return 0, cand_soft, cand_sizes
    soft = np.concatenate(
        [np.asarray(buffer_soft, np.float64), cand_soft], axis=0)
    sizes = np.concatenate(
        [np.asarray(buffer_sizes, np.float64), cand_sizes], axis=0)
    return nb, soft, sizes


def admit_candidates(judge_obj, buffer_soft, buffer_sizes, cand_soft,
                     cand_sizes) -> tuple[list[int], list[int], float]:
    """Admission fallback for judges without an ``admit`` method.

    Runs the judge once over buffer ∪ candidates and reads the verdicts
    for the candidate rows only (*relative* to the candidate block;
    rejected in removal order). Buffered rows have already shipped their
    weights, so a verdict against one of them is ignored here — judges
    that must never "re-litigate" the buffer implement ``admit`` with a
    protected sweep instead (see :meth:`MaxEntropyJudge.admit`).
    """
    nb, soft, sizes = _stack_buffer(buffer_soft, buffer_sizes,
                                    cand_soft, cand_sizes)
    accepted, rejected, ent = judge_obj(soft, sizes)
    return ([i - nb for i in accepted if i >= nb],
            [i - nb for i in rejected if i >= nb], ent)


@register("judge", "maxent")
class MaxEntropyJudge:
    """Paper Algorithm 1: drop devices whose removal raises group entropy.

    backend: "numpy" (float64 host oracle), "xla" (traced float32
    leave-one-out sweep) or "pallas" (class-axis-tiled kernel).
    """

    def __init__(self, backend: str = "numpy"):
        if backend not in ("numpy", "xla", "pallas"):
            raise ValueError(f"unknown judge backend {backend!r}")
        self.backend = backend
        self._jitted = None       # compiled host-call path, built lazily
        self._jitted_admit = None  # compiled protected-sweep path (async)

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        if self.backend == "numpy":
            return judge_np(soft_labels, sizes)
        if self._jitted is None:  # don't re-trace the while_loop per round
            self._jitted = jax.jit(self.traced())
        res = self._jitted(jnp.asarray(soft_labels, jnp.float32),
                           jnp.asarray(sizes, jnp.float32))
        return _result_to_lists(res)

    def traced(self):
        """Jit-compatible (soft_labels, sizes) -> JudgmentResult; numpy
        backend falls back to the xla sweep (same greedy, float32)."""
        backend = "xla" if self.backend == "numpy" else self.backend
        return lambda soft, sizes: judge(soft, sizes, backend=backend)

    def admit(self, buffer_soft, buffer_sizes, cand_soft, cand_sizes
              ) -> tuple[list[int], list[int], float]:
        """Per-arrival admission for the async engine: Algorithm 1's greedy
        removal over buffer ∪ candidates, with the buffered rows *protected*
        — they contribute to the group entropy (their weights already
        shipped) but are never removal candidates. Returns
        ``(admitted, rejected, entropy)`` relative to the candidate block,
        rejected in removal order; with an empty buffer this is exactly the
        round judgment ``__call__`` runs, which is what makes the
        K=|cohort| zero-latency reduction bit-for-bit.
        """
        nb, soft, sizes = _stack_buffer(buffer_soft, buffer_sizes,
                                        cand_soft, cand_sizes)
        if nb == 0:
            return self(soft, sizes)
        if self.backend == "numpy":
            prot = np.zeros(len(sizes))
            prot[:nb] = 1.0
            accepted, rejected, ent = judge_np(soft, sizes, protected=prot)
        else:
            if self._jitted_admit is None:
                backend = self.backend
                self._jitted_admit = jax.jit(
                    lambda s, z, p: judge(s, z, backend=backend,
                                          protected=p))
            prot = jnp.zeros((len(sizes),), jnp.float32).at[:nb].set(1.0)
            res = self._jitted_admit(jnp.asarray(soft, jnp.float32),
                                     jnp.asarray(sizes, jnp.float32), prot)
            accepted, rejected, ent = _result_to_lists(res)
        return ([i - nb for i in accepted if i >= nb],
                [i - nb for i in rejected if i >= nb], ent)


@register("judge", "none")
class PassThroughJudge:
    """Admit every selected device; entropy is not defined (NaN)."""

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        return list(range(len(sizes))), [], float("nan")

    def traced(self):
        def all_in(soft, sizes):
            m = soft.shape[0]
            ones = jnp.ones((m,), jnp.float32)
            nan = jnp.full((), jnp.nan, jnp.float32)
            return JudgmentResult(
                mask=ones, entropy=nan, initial_entropy=nan,
                num_removed=jnp.zeros((), jnp.int32),
                removal_order=jnp.full((m,), -1, jnp.int32))
        return all_in


@register("judge", "budget")
class BudgetedJudge:
    """Keep exactly ``budget`` devices, forward-greedy on group entropy."""

    def __init__(self, budget: int):
        self.budget = int(budget)

    @classmethod
    def from_config(cls, config, local):
        raise ValueError(
            "BudgetedJudge needs an explicit budget — pass an instance, "
            "e.g. build(..., judge=BudgetedJudge(budget=3))")

    def __call__(self, soft_labels: np.ndarray, sizes: np.ndarray
                 ) -> tuple[list[int], list[int], float]:
        res = judge_budgeted(jnp.asarray(soft_labels, jnp.float32),
                             jnp.asarray(sizes, jnp.float32), self.budget)
        return _result_to_lists(res)

    def traced(self):
        budget = self.budget
        return lambda soft, sizes: judge_budgeted(soft, sizes, budget)
