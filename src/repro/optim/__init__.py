from .optim import Optimizer, adamw, sgd
