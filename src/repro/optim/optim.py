"""Pure-pytree optimizers (no optax in this environment).

``sgd``   — SGD with (optionally Nesterov-free) momentum; the paper's local
            optimizer (lr 0.01, momentum 0.5) and the default for the
            mesh-scale FL driver (momentum state is the only extra copy,
            which is what lets kimi-k2 fit FSDP-sharded).
``adamw`` — AdamW for non-FL baselines and fine-tuning examples.

Each factory returns ``Optimizer(init, update)`` where
``update(grads, state, params) -> (new_params, new_state)``.
State trees mirror the param tree, so param shardings apply verbatim.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def sgd(lr: float = 0.01, momentum: float = 0.5,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum == 0.0:
            new_p = jax.tree.map(
                lambda p, g: p - (lr * g).astype(p.dtype), params, grads)
            return new_p, {"count": state["count"] + 1}
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        new_p = jax.tree.map(lambda p, m: p - (lr * m).astype(p.dtype),
                             params, mu)
        return new_p, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, update)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return p - (lr * upd).astype(p.dtype)
        new_p = jax.tree.map(step, params, m, v)
        return new_p, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)
