from .io import latest_step, load, restore, save
