"""Flat-key npz pytree checkpointing with step retention.

``save(dir, step, tree)`` writes ``step_<n>.npz`` with '/'-joined keys,
atomically (tmp + rename). ``restore(dir, like)`` loads the latest step
back into the structure of ``like`` (dtypes/shapes validated). Pool state
and other host-side metadata ride along in a ``__meta__`` JSON entry.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, meta: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    if meta is not None:
        flat["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        os.remove(os.path.join(ckpt_dir, f"step_{s:08d}.npz"))
    return path


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return [int(f[5:-4]) for f in os.listdir(ckpt_dir)
            if f.startswith("step_") and f.endswith(".npz")]


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def load(ckpt_dir: str, step: int) -> tuple[dict[str, np.ndarray], dict]:
    with np.load(os.path.join(ckpt_dir, f"step_{step:08d}.npz")) as z:
        flat = {k: z[k] for k in z.files}
    meta = {}
    if "__meta__" in flat:
        meta = json.loads(flat.pop("__meta__").tobytes().decode())
    return flat, meta


def restore(ckpt_dir: str, like, step: int | None = None
            ) -> tuple[Any, dict, int]:
    """Load latest (or given) step into the structure of ``like``."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    flat, meta = load(ckpt_dir, step)
    ref = _flatten(like)
    missing = set(ref) - set(flat)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}…")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    new_leaves = []
    for key, leaf in zip(keys, leaves):
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta, step
