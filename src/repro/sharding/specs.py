"""Divisibility-aware logical->mesh sharding rules.

Every tensor dimension carries a *logical axis name*; the rule table maps
names to (tuples of) mesh axes. A mesh axis is applied only if it divides
the dimension — otherwise we retry with a shorter prefix and finally
replicate. This is what lets one rule table cover kv=2 (replicated on a
16-way "model" axis) and kv=16 (sharded) without per-arch special cases.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (tried longest-prefix-first)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fl_clients": ("pod", "data"),
    "seq": (),
    "embed": ("pod", "data"),        # FSDP axis for params
    "vocab": ("model",),
    "heads": ("model",),             # fused num_heads*head_dim dims
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "capacity": ("pod", "data"),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "frames": (),
    "kv_time": (),
    None: (),
}


def _axes_for(dim: int, names: Sequence[str], mesh: Mesh) -> Optional[tuple]:
    """Longest prefix of mesh axes whose product divides ``dim``."""
    live = [n for n in names if n in mesh.shape]
    for end in range(len(live), 0, -1):
        pick = live[:end]
        prod = int(np.prod([mesh.shape[n] for n in pick]))
        if prod > 1 and dim % prod == 0:
            return tuple(pick) if len(pick) > 1 else pick[0]
    return None


def logical_to_pspec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict | None = None,
) -> P:
    rules = rules or DEFAULT_RULES
    parts, used = [], set()
    for dim, name in zip(shape, logical):
        cand = rules.get(name, ())
        cand = tuple(a for a in cand if a not in used)
        ax = _axes_for(dim, cand, mesh) if cand else None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        parts.append(ax)
    return P(*parts)


def tree_shardings(logical_tree, shape_tree, mesh: Mesh, rules=None):
    """Map a tree of logical-axis tuples + matching ShapeDtypeStructs to
    NamedShardings."""
    def one(logical, sds):
        return NamedSharding(
            mesh, logical_to_pspec(logical, sds.shape, mesh, rules))
    return jax.tree.map(one, logical_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
