from .ctx import ShardingCtx, current, shard_act, use_mesh
from .specs import DEFAULT_RULES, logical_to_pspec, tree_shardings
