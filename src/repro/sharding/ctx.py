"""Activation-sharding context.

Model code annotates hot activations with ``shard_act(x, ("batch", "seq",
"embed"))``. Outside a distribution context (unit tests, the vmapped FL
simulator) this is the identity; inside ``use_mesh(mesh)`` it becomes
``jax.lax.with_sharding_constraint`` with the divisibility-aware rule table.
This keeps model definitions mesh-agnostic while giving the dry-run full
control of activation layouts.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from .specs import DEFAULT_RULES, logical_to_pspec

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_ctx", default=None)


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: dict | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def constraint(self, x, logical: Sequence[Optional[str]]):
        spec = logical_to_pspec(logical, x.shape, self.mesh, self.rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    tok = _CTX.set(ShardingCtx(mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current() -> Optional[ShardingCtx]:
    return _CTX.get()


def shard_act(x, logical: Sequence[Optional[str]]):
    ctx = _CTX.get()
    if ctx is None:
        return x
    return ctx.constraint(x, logical)
