"""Non-IID client partitioners — the paper's three heterogeneity settings
(Sec. 4.1, Fig. 4):

* case 1 — every client holds samples of a SINGLE label;
* case 2 — every client holds samples of exactly TWO labels, evenly;
* case 3 — label proportions per client drawn from Dirichlet(beta), beta=0.1.

``stack_clients`` pads per-client datasets to a common length and emits the
(x, y, w) stacked arrays consumed by the vmapped simulator (w masks padding).
``drift_schedule`` generates deterministic *distribution drift* events: at
a scheduled round a seeded subset of clients re-partitions onto fresh label
shards, so selector/judgment quality can be measured under non-stationarity
instead of only the static cases above (the server applies the events —
see ``repro.fl.Server``'s ``drift=`` keyword).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _by_class(y: np.ndarray, num_classes: int, rng) -> list[np.ndarray]:
    out = []
    for c in range(num_classes):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        out.append(idx)
    return out


def partition_case1(y, num_clients, num_classes, seed=0):
    """Single label per client; clients cycle through the classes."""
    rng = np.random.default_rng(seed)
    pools = _by_class(y, num_classes, rng)
    cls_of = [i % num_classes for i in range(num_clients)]
    counts = np.bincount(cls_of, minlength=num_classes)
    parts, used = [], np.zeros(num_classes, np.int64)
    for i in range(num_clients):
        c = cls_of[i]
        share = len(pools[c]) // counts[c]
        parts.append(pools[c][used[c]: used[c] + share])
        used[c] += share
    return parts


def partition_case2(y, num_clients, num_classes, seed=0):
    """Exactly two labels per client, evenly split (paper case 2)."""
    rng = np.random.default_rng(seed)
    pools = _by_class(y, num_classes, rng)
    # pair classes (c, c+1 mod C) cycling over clients
    pair_of = [(i % num_classes, (i + 1) % num_classes)
               for i in range(num_clients)]
    per_class_users = np.zeros(num_classes, np.int64)
    for a, b in pair_of:
        per_class_users[a] += 1
        per_class_users[b] += 1
    used = np.zeros(num_classes, np.int64)
    parts = []
    for a, b in pair_of:
        pa = len(pools[a]) // per_class_users[a]
        pb = len(pools[b]) // per_class_users[b]
        take = min(pa, pb)
        pt = np.concatenate([pools[a][used[a]:used[a] + take],
                             pools[b][used[b]:used[b] + take]])
        used[a] += take
        used[b] += take
        rng.shuffle(pt)
        parts.append(pt)
    return parts


def partition_dirichlet(y, num_clients, num_classes, beta=0.1, seed=0,
                        min_samples=2, max_retries=1000):
    """Dirichlet(beta) label proportions per client (paper case 3).

    Draws are resampled until every client holds at least ``min_samples``;
    an infeasible (beta, min_samples, N) combination fails loudly after
    ``max_retries`` attempts instead of hanging the run.
    """
    rng = np.random.default_rng(seed)
    for _ in range(max(1, int(max_retries))):
        pools = _by_class(y, num_classes, rng)
        parts = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            props = rng.dirichlet(np.full(num_clients, beta))
            cuts = (np.cumsum(props) * len(pools[c])).astype(int)[:-1]
            for i, chunk in enumerate(np.split(pools[c], cuts)):
                parts[i].append(chunk)
        parts = [np.concatenate(p) for p in parts]
        if min(len(p) for p in parts) >= min_samples:
            return [rng.permutation(p) for p in parts]
    raise RuntimeError(
        f"partition_dirichlet: no draw gave every one of {num_clients} "
        f"clients >= {min_samples} samples after {max_retries} resamples "
        f"(beta={beta}, {len(y)} samples); lower min_samples, raise beta, "
        "or reduce num_clients")


def partition(case: str, y, num_clients, num_classes, seed=0, beta=0.1):
    if case == "case1":
        return partition_case1(y, num_clients, num_classes, seed)
    if case == "case2":
        return partition_case2(y, num_clients, num_classes, seed)
    if case in ("case3", "dirichlet"):
        return partition_dirichlet(y, num_clients, num_classes, beta, seed)
    raise ValueError(f"unknown heterogeneity case: {case}")


def stack_clients(x, y, parts, batch_multiple: int = 1):
    """Pad client shards to a common length -> stacked {x, y, w} arrays.

    The common length is rounded up to ``batch_multiple`` so every client
    dataset reshapes exactly into local minibatches.
    """
    smax = max(len(p) for p in parts)
    if batch_multiple > 1:
        smax = int(np.ceil(smax / batch_multiple) * batch_multiple)
    n = len(parts)
    xs = np.zeros((n, smax) + x.shape[1:], x.dtype)
    ys = np.zeros((n, smax), np.int32)
    ws = np.zeros((n, smax), np.float32)
    for i, p in enumerate(parts):
        xs[i, :len(p)] = x[p]
        ys[i, :len(p)] = y[p]
        ws[i, :len(p)] = 1.0
    return {"x": xs, "y": ys, "w": ws}


def label_histogram(y, parts, num_classes):
    return np.stack([np.bincount(y[p], minlength=num_classes)
                     for p in parts])


# --------------------------------------------------------------- drift

@dataclass(frozen=True)
class DriftEvent:
    """One scheduled drift: at round ``round`` the listed clients swap
    their stacked rows for ``data`` (same ``{x, y, w}`` layout and
    per-client sample length as the corpus they drift inside)."""
    round: int
    clients: tuple
    data: dict

    def __post_init__(self):
        if self.round < 0:
            raise ValueError("drift round must be >= 0")
        if len(set(self.clients)) != len(self.clients):
            raise ValueError("drift clients must be distinct")
        rows = {k: np.shape(v)[0] for k, v in self.data.items()}
        if any(r != len(self.clients) for r in rows.values()):
            raise ValueError(
                f"drift data rows {rows} must match the "
                f"{len(self.clients)} drifting clients")


def _restack(x, y, shards, samples_per_client: int):
    """``stack_clients`` for a client subset at a FIXED common length
    (the corpus's existing per-client sample axis): shards longer than
    the corpus row truncate, shorter ones pad with w=0."""
    s = int(samples_per_client)
    k = len(shards)
    xs = np.zeros((k, s) + x.shape[1:], x.dtype)
    ys = np.zeros((k, s), np.int32)
    ws = np.zeros((k, s), np.float32)
    for i, p in enumerate(shards):
        p = np.asarray(p)[:s]
        xs[i, :len(p)] = x[p]
        ys[i, :len(p)] = y[p]
        ws[i, :len(p)] = 1.0
    return {"x": xs, "y": ys, "w": ws}


def drift_schedule(x, y, num_clients, num_classes, *, at, frac=0.5,
                   case="case1", seed=0, beta=0.1,
                   samples_per_client=None) -> list:
    """Deterministic drift events: at each round in ``at``, a seeded
    ``frac`` of clients re-partition onto fresh label shards.

    Each event draws its own client subset and a fresh :func:`partition`
    (seed derived from ``seed`` and the event index, so the whole
    schedule is a pure function of its arguments), then assigns drifting
    client ``c`` the shard of rotated client ``c+1`` — under case1/case2
    that *changes the label distribution*, not just the samples. Every
    drifting client is re-partitioned exactly once per event, and no
    event fires before ``min(at)``.

    ``samples_per_client`` pins the stacked row length to the corpus the
    events will be applied to (required: the server validates shapes at
    application time). Returns a list of :class:`DriftEvent`, sorted by
    round.
    """
    if samples_per_client is None:
        raise ValueError(
            "samples_per_client is required (the corpus's per-client "
            "sample axis the replacement rows must match)")
    if not 0.0 < frac <= 1.0:
        raise ValueError("frac must be in (0, 1]")
    rounds = (int(at),) if np.isscalar(at) else tuple(int(r) for r in at)
    if len(set(rounds)) != len(rounds):
        raise ValueError("drift rounds must be distinct")
    events = []
    for j, r in enumerate(sorted(rounds)):
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), j, r]))
        k = max(1, int(np.round(frac * num_clients)))
        drifting = np.sort(rng.choice(num_clients, size=k, replace=False))
        parts = partition(case, y, num_clients, num_classes,
                          seed=int(seed) + 1 + j, beta=beta)
        shards = [parts[(int(c) + 1) % num_clients] for c in drifting]
        events.append(DriftEvent(
            round=r, clients=tuple(int(c) for c in drifting),
            data=_restack(x, y, shards, samples_per_client)))
    return events
