"""Non-IID client partitioners — the paper's three heterogeneity settings
(Sec. 4.1, Fig. 4):

* case 1 — every client holds samples of a SINGLE label;
* case 2 — every client holds samples of exactly TWO labels, evenly;
* case 3 — label proportions per client drawn from Dirichlet(beta), beta=0.1.

``stack_clients`` pads per-client datasets to a common length and emits the
(x, y, w) stacked arrays consumed by the vmapped simulator (w masks padding).
"""
from __future__ import annotations

import numpy as np


def _by_class(y: np.ndarray, num_classes: int, rng) -> list[np.ndarray]:
    out = []
    for c in range(num_classes):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        out.append(idx)
    return out


def partition_case1(y, num_clients, num_classes, seed=0):
    """Single label per client; clients cycle through the classes."""
    rng = np.random.default_rng(seed)
    pools = _by_class(y, num_classes, rng)
    cls_of = [i % num_classes for i in range(num_clients)]
    counts = np.bincount(cls_of, minlength=num_classes)
    parts, used = [], np.zeros(num_classes, np.int64)
    for i in range(num_clients):
        c = cls_of[i]
        share = len(pools[c]) // counts[c]
        parts.append(pools[c][used[c]: used[c] + share])
        used[c] += share
    return parts


def partition_case2(y, num_clients, num_classes, seed=0):
    """Exactly two labels per client, evenly split (paper case 2)."""
    rng = np.random.default_rng(seed)
    pools = _by_class(y, num_classes, rng)
    # pair classes (c, c+1 mod C) cycling over clients
    pair_of = [(i % num_classes, (i + 1) % num_classes)
               for i in range(num_clients)]
    per_class_users = np.zeros(num_classes, np.int64)
    for a, b in pair_of:
        per_class_users[a] += 1
        per_class_users[b] += 1
    used = np.zeros(num_classes, np.int64)
    parts = []
    for a, b in pair_of:
        pa = len(pools[a]) // per_class_users[a]
        pb = len(pools[b]) // per_class_users[b]
        take = min(pa, pb)
        pt = np.concatenate([pools[a][used[a]:used[a] + take],
                             pools[b][used[b]:used[b] + take]])
        used[a] += take
        used[b] += take
        rng.shuffle(pt)
        parts.append(pt)
    return parts


def partition_dirichlet(y, num_clients, num_classes, beta=0.1, seed=0,
                        min_samples=2, max_retries=1000):
    """Dirichlet(beta) label proportions per client (paper case 3).

    Draws are resampled until every client holds at least ``min_samples``;
    an infeasible (beta, min_samples, N) combination fails loudly after
    ``max_retries`` attempts instead of hanging the run.
    """
    rng = np.random.default_rng(seed)
    for _ in range(max(1, int(max_retries))):
        pools = _by_class(y, num_classes, rng)
        parts = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            props = rng.dirichlet(np.full(num_clients, beta))
            cuts = (np.cumsum(props) * len(pools[c])).astype(int)[:-1]
            for i, chunk in enumerate(np.split(pools[c], cuts)):
                parts[i].append(chunk)
        parts = [np.concatenate(p) for p in parts]
        if min(len(p) for p in parts) >= min_samples:
            return [rng.permutation(p) for p in parts]
    raise RuntimeError(
        f"partition_dirichlet: no draw gave every one of {num_clients} "
        f"clients >= {min_samples} samples after {max_retries} resamples "
        f"(beta={beta}, {len(y)} samples); lower min_samples, raise beta, "
        "or reduce num_clients")


def partition(case: str, y, num_clients, num_classes, seed=0, beta=0.1):
    if case == "case1":
        return partition_case1(y, num_clients, num_classes, seed)
    if case == "case2":
        return partition_case2(y, num_clients, num_classes, seed)
    if case in ("case3", "dirichlet"):
        return partition_dirichlet(y, num_clients, num_classes, beta, seed)
    raise ValueError(f"unknown heterogeneity case: {case}")


def stack_clients(x, y, parts, batch_multiple: int = 1):
    """Pad client shards to a common length -> stacked {x, y, w} arrays.

    The common length is rounded up to ``batch_multiple`` so every client
    dataset reshapes exactly into local minibatches.
    """
    smax = max(len(p) for p in parts)
    if batch_multiple > 1:
        smax = int(np.ceil(smax / batch_multiple) * batch_multiple)
    n = len(parts)
    xs = np.zeros((n, smax) + x.shape[1:], x.dtype)
    ys = np.zeros((n, smax), np.int32)
    ws = np.zeros((n, smax), np.float32)
    for i, p in enumerate(parts):
        xs[i, :len(p)] = x[p]
        ys[i, :len(p)] = y[p]
        ws[i, :len(p)] = 1.0
    return {"x": xs, "y": ys, "w": ws}


def label_histogram(y, parts, num_classes):
    return np.stack([np.bincount(y[p], minlength=num_classes)
                     for p in parts])
