from . import corpus, ingest, partition, stream, synthetic
from .corpus import ClientCorpus, DataQueue, Normalize, pad_client_axis
from .ingest import (
    load_cifar10, load_cifar100, load_cinic10, load_image_corpus,
)
from .stream import CohortPrefetcher, HostCorpus, as_data_plane

__all__ = [
    "ClientCorpus", "CohortPrefetcher", "DataQueue", "HostCorpus",
    "Normalize", "as_data_plane", "corpus", "ingest",
    "load_cifar10", "load_cifar100", "load_cinic10", "load_image_corpus",
    "pad_client_axis", "partition", "stream", "synthetic",
]
