from . import corpus, ingest, partition, synthetic
from .corpus import ClientCorpus, DataQueue, Normalize, pad_client_axis
from .ingest import (
    load_cifar10, load_cifar100, load_cinic10, load_image_corpus,
)

__all__ = [
    "ClientCorpus", "DataQueue", "Normalize", "corpus", "ingest",
    "load_cifar10", "load_cifar100", "load_cinic10", "load_image_corpus",
    "pad_client_axis", "partition", "synthetic",
]
