from . import partition, synthetic
