"""Streaming host-resident data plane: the million-client residency
contract.

:class:`repro.data.corpus.ClientCorpus` stacks *all* N clients on the
accelerator — perfect at the paper's N=100, impossible at the
cross-device IoT scale the paper frames (N=10^6). This module inverts the
residency contract:

* :class:`HostCorpus` keeps the stacked ``x/y/w`` arrays **host-side**
  (plain numpy or ``np.load(mmap_mode="r")`` memory maps — see
  :meth:`HostCorpus.save` / :meth:`HostCorpus.open` and the packed
  ``.npy`` ingest cache in :mod:`repro.data.ingest`), and only the
  per-round *cohort* ever becomes device-resident: ``cohort(idx)`` is a
  host gather + H2D upload + the same traced ``Normalize``/queue-mask
  program the resident plane fuses into its gather. Device bytes are
  O(|S_t|), never O(N).
* The control plane scales with it: ``sizes()`` / ``label_histograms()``
  / ``label_entropy()`` — the stats selectors rank and group on — are
  computed in **one streaming pass over client chunks at open time**,
  never materializing a dense (N, S, ...) float corpus anywhere. The
  per-chunk math is exactly the dense math (same
  ``core.pools.label_histograms`` rows, same row-local reductions), so
  streamed stats equal :class:`ClientCorpus`'s bit-for-bit.
* :class:`CohortPrefetcher` overlaps round t's compute with round t+1's
  upload: a background thread gathers the *speculated* next selection
  into double-buffered staging arrays and ships them to the device while
  the main thread blocks in the float64 judgment oracle.
  ``PipelinedServer``'s verdict speculation predicts the next selection
  early (the same throwaway-selector draw it already dispatches against);
  on a selector misprediction the staged buffers are discarded and the
  next round falls back to a synchronous gather.

Both planes share the ``signature()`` contract — the plane is part of
the key, so compiled programs built against one plane are never served
to the other — and both answer :func:`memory_report` with plane-aware
host-mapped / device-resident / staging byte accounting.

:func:`as_data_plane` is the single wiring point ``repro.fl`` builds
through: ``"resident"`` / ``"streaming"`` force a plane, ``"auto"``
(default) keeps the resident fast path while the corpus fits
(:data:`RESIDENT_BUDGET_BYTES`) and streams past it.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections.abc import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import CLIENT_AXIS, ClientCorpus, Normalize

PLANES = ("resident", "streaming", "auto")

# "auto" keeps the corpus device-resident while its storage-dtype bytes
# fit this budget, and streams past it (override per call site). The
# default is deliberately conservative: every paper-scale corpus in the
# repo is a few MB, so existing compositions keep the resident fast path.
RESIDENT_BUDGET_BYTES = 1 << 30

# clients per streaming-stats chunk: bounds the host working set of the
# open-time pass at chunk * S * itemsize bytes regardless of N
STATS_CHUNK_CLIENTS = 4096


def _host_array(v) -> np.ndarray:
    """Device/host array -> host numpy, preserving dtype; memory maps and
    existing ndarrays pass through without a copy."""
    if isinstance(v, np.ndarray):
        return v
    return np.asarray(v)


class HostCorpus(Mapping):
    """Host-resident stacked client corpus; see the module docstring.

    Shares :class:`ClientCorpus`'s surface — ``Mapping`` over the raw
    arrays, ``cohort(idx, active=None)``, ``signature()``, the cached
    control-plane stats, ``shard(mesh)`` (placement *recording* here:
    uploads replicate over the mesh, the corpus itself never moves) —
    so servers, selectors, and strategies take either plane unchanged.
    """

    plane = "streaming"

    def __init__(self, arrays: dict, *, transform: Normalize | None = None,
                 stats_chunk: int = STATS_CHUNK_CLIENTS,
                 prefetch_depth: int = 1):
        if not arrays:
            raise ValueError("HostCorpus needs at least one array")
        n = {k: np.shape(v)[0] for k, v in arrays.items()}
        if len(set(n.values())) != 1:
            raise ValueError(f"client axes disagree: {n}")
        self._arrays = {k: _host_array(v) for k, v in arrays.items()}
        self.transform = transform
        self.prefetch_depth = int(prefetch_depth)
        if self.prefetch_depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self._mesh = None
        self._n = int(next(iter(self._arrays.values())).shape[0])
        self._stats_chunk = max(1, int(stats_chunk))
        self._finish = jax.jit(self._finish_impl)
        self._finish_queued = jax.jit(self._finish_queued_impl)
        self._prefetcher: CohortPrefetcher | None = None
        self._uploaded_nbytes = 0        # most recent cohort's device bytes
        # one streaming pass at open time: sizes + histograms + entropy
        self._hists: dict = {}
        self._sizes, self._hists[None], self._entropy = self._stream_stats()

    # ------------------------------------------------------- constructors
    @classmethod
    def from_stacked(cls, data, *, transform: Normalize | None = None
                     ) -> "HostCorpus":
        """Wrap a stacked dict / either corpus; identity on a HostCorpus."""
        if isinstance(data, HostCorpus):
            return data
        if isinstance(data, ClientCorpus):
            return cls(data.as_numpy(), transform=data.transform
                       if transform is None else transform)
        return cls(dict(data), transform=transform)

    @classmethod
    def from_parts(cls, x, y, parts, *, batch_multiple: int = 1,
                   transform: Normalize | None = None) -> "HostCorpus":
        from .partition import stack_clients
        return cls(stack_clients(x, y, parts, batch_multiple),
                   transform=transform)

    # ------------------------------------------------------ mmap open/save
    def save(self, directory: str) -> str:
        """Write each array as ``<directory>/<key>.npy`` plus a meta.json
        (transform policy included), the layout :meth:`open` memory-maps.
        Returns ``directory``."""
        os.makedirs(directory, exist_ok=True)
        for k, v in self._arrays.items():
            np.save(os.path.join(directory, f"{k}.npy"), v)
        meta = {"keys": sorted(self._arrays)}
        if self.transform is not None:
            t = self.transform
            meta["transform"] = {"scale": t.scale, "mean": list(t.mean),
                                 "std": list(t.std)}
        with open(os.path.join(directory, "meta.json"), "w") as f:
            json.dump(meta, f)
        return directory

    @classmethod
    def open(cls, directory: str, *,
             transform: Normalize | None = None) -> "HostCorpus":
        """Memory-map a :meth:`save` layout (``np.load(mmap_mode="r")``):
        opening N=10^6 clients touches pages only as cohorts gather them.
        ``transform=None`` restores the saved policy, if any."""
        meta_path = os.path.join(directory, "meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        arrays = {k: np.load(os.path.join(directory, f"{k}.npy"),
                             mmap_mode="r") for k in meta["keys"]}
        if transform is None and "transform" in meta:
            t = meta["transform"]
            transform = Normalize(scale=t["scale"], mean=tuple(t["mean"]),
                                  std=tuple(t["std"]))
        return cls(arrays, transform=transform)

    # ---------------------------------------------------- Mapping protocol
    def __getitem__(self, key):
        return self._arrays[key]

    def __iter__(self):
        return iter(self._arrays)

    def __len__(self):
        return len(self._arrays)

    # ----------------------------------------------------------- metadata
    @property
    def num_clients(self) -> int:
        return self._n

    @property
    def padded_num_clients(self) -> int:
        """The streaming plane never pads: cohorts, not the corpus, meet
        the mesh (``make_sharded_client_fn`` pads the cohort in-trace)."""
        return self._n

    @property
    def client_valid(self) -> np.ndarray:
        return np.ones(self._n, bool)

    @property
    def samples_per_client(self) -> int:
        return int(self._arrays["y"].shape[1]) if "y" in self._arrays \
            else int(next(iter(self._arrays.values())).shape[1])

    def signature(self) -> tuple:
        """Hashable key carrying the *plane* plus shapes/dtypes/transform:
        a compiled program built against the streaming plane must never be
        served to a resident corpus or vice versa."""
        return ("stream",
                tuple((k, tuple(v.shape), str(v.dtype))
                      for k, v in sorted(self._arrays.items())),
                self.transform)

    @property
    def nbytes(self) -> int:
        """Host-resident (or host-mapped) bytes of the stored corpus."""
        return int(sum(int(v.size) * v.dtype.itemsize
                       for v in self._arrays.values()))

    def device_nbytes(self) -> int:
        """Device bytes the plane currently holds: the most recent staged
        cohort (plus any in-flight prefetch) — O(|S_t|), never O(N)."""
        inflight = (self._prefetcher.inflight_nbytes
                    if self._prefetcher is not None else 0)
        return int(self._uploaded_nbytes + inflight)

    def cohort_nbytes(self, m: int) -> int:
        """Bytes a float32 host-slice plane would ship per round for an
        ``m``-client cohort (same accounting as the resident plane)."""
        total = 0
        for k, v in self._arrays.items():
            itemsize = (4 if k == "x" and self.transform is not None
                        else v.dtype.itemsize)
            total += int(np.prod(v.shape[1:], dtype=np.int64)) * itemsize * m
        return total

    def as_numpy(self) -> dict:
        return {k: np.asarray(v) for k, v in self._arrays.items()}

    def memory_report(self) -> dict:
        """Plane-aware byte accounting (the satellite contract):
        host-mapped bytes, device-resident bytes, staging-buffer bytes."""
        pf = self._prefetcher
        return {
            "plane": self.plane,
            "host_mapped_bytes": self.nbytes,
            "host_is_mmap": any(isinstance(v, np.memmap)
                                for v in self._arrays.values()),
            "device_resident_bytes": self.device_nbytes(),
            "staging_nbytes": 0 if pf is None else pf.staging_nbytes,
            "num_clients": self._n,
        }

    # ------------------------------------------------- control-plane stats
    def _stream_stats(self):
        """One pass over client chunks: per-client sizes, label histograms
        (inferred global class width), and label entropy.

        Each chunk runs the identical per-row math the dense plane runs
        (``core.pools.label_histograms`` / ``hist_entropy``; row-local
        float32 weight sums), so the streamed results are bit-for-bit the
        dense results at any N — the plane-equivalence property the tests
        hold.
        """
        from ..core.pools import hist_entropy, label_histograms
        y = self._arrays.get("y")
        w = self._arrays.get("w")
        sizes = np.empty(self._n, np.int64)
        chunks: list[np.ndarray] = []
        width = 0
        for lo in range(0, self._n, self._stats_chunk):
            hi = min(lo + self._stats_chunk, self._n)
            wc = None if w is None else np.asarray(w[lo:hi])
            if wc is None:
                sizes[lo:hi] = self.samples_per_client
            else:
                # row-local float32 sums: exactly the resident plane's
                # jnp.sum(w, axis=1) for the 0/1 masks stack_clients emits
                sizes[lo:hi] = np.sum(
                    wc.astype(np.float32), axis=1).astype(np.int64)
            if y is not None:
                h = label_histograms(np.asarray(y[lo:hi]), wc)
                width = max(width, h.shape[1])
                chunks.append(h)
        if y is None:
            return sizes, None, np.zeros(self._n, np.float64)
        hists = np.zeros((self._n, width), np.float64)
        lo = 0
        for h in chunks:
            hists[lo:lo + h.shape[0], :h.shape[1]] = h
            lo += h.shape[0]
        ent = np.asarray([hist_entropy(h) for h in hists], np.float64)
        return sizes, hists, ent

    def sizes(self) -> np.ndarray:
        return self._sizes

    def label_histograms(self, num_classes: int | None = None) -> np.ndarray:
        """(N, C) weighted label counts, streamed; the default width was
        computed at open time, explicit widths stream a fresh pass (cached
        per ``num_classes``, like the resident plane)."""
        if num_classes not in self._hists:
            from ..core.pools import label_histograms
            y, w = self._arrays["y"], self._arrays.get("w")
            rows = []
            for lo in range(0, self._n, self._stats_chunk):
                hi = min(lo + self._stats_chunk, self._n)
                rows.append(label_histograms(
                    np.asarray(y[lo:hi]),
                    None if w is None else np.asarray(w[lo:hi]),
                    num_classes=num_classes))
            self._hists[num_classes] = np.concatenate(rows, axis=0)
        return self._hists[num_classes]

    def label_entropy(self) -> np.ndarray:
        return self._entropy

    # ------------------------------------------------------------ placement
    def shard(self, mesh, axis: str = CLIENT_AXIS) -> "HostCorpus":
        """Record the mesh cohort uploads replicate over. The corpus
        itself never moves — streaming *is* the placement. Returns self
        (same idempotent contract as the resident plane)."""
        self._mesh = mesh
        return self

    def _place(self, v: np.ndarray) -> jax.Array:
        if self._mesh is None:
            return jnp.asarray(v)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(v, NamedSharding(self._mesh, P()))

    # ------------------------------------------------------------ data plane
    def prefetcher(self) -> "CohortPrefetcher":
        """The (lazily created) background prefetcher; :meth:`prefetch`
        and :meth:`cohort` route through it. ``prefetch_depth`` (a
        construction knob, default 1) sets how many predicted cohorts may
        stage ahead — 1 is the classic double-buffered single-slot."""
        if self._prefetcher is None:
            self._prefetcher = CohortPrefetcher(self, self.prefetch_depth)
        return self._prefetcher

    def prefetch(self, idx, active=None) -> None:
        """Start staging cohort ``idx`` (host gather + H2D) on the
        background thread. A later :meth:`cohort` with the same (idx,
        active) consumes the staged upload; :meth:`cancel_prefetch`
        discards it (selector misprediction)."""
        self.prefetcher().start(np.asarray(idx, np.int64),
                                None if active is None
                                else np.asarray(active, np.int64))

    def cancel_prefetch(self) -> None:
        if self._prefetcher is not None:
            self._prefetcher.cancel()

    def prefetch_stats(self) -> dict:
        return (CohortPrefetcher.empty_stats() if self._prefetcher is None
                else self._prefetcher.stats())

    def _gather_host(self, idx: np.ndarray) -> dict:
        """Host fancy-gather of the cohort rows, storage dtype (memory
        maps touch only the selected pages)."""
        return {k: np.asarray(v[idx]) for k, v in self._arrays.items()}

    def _upload(self, staged: dict) -> dict:
        up = {k: self._place(v) for k, v in staged.items()}
        self._uploaded_nbytes = sum(int(v.size) * v.dtype.itemsize
                                    for v in up.values())
        return up

    def _finish_impl(self, data: dict) -> dict:
        out = dict(data)
        if self.transform is not None and "x" in out:
            out["x"] = self.transform(out["x"])
        return out

    def _finish_queued_impl(self, data: dict, active: jax.Array) -> dict:
        out = self._finish_impl(data)
        if "w" in out:
            s = out["w"].shape[1]
            live = jnp.arange(s)[None, :] < active[:, None]
            out["w"] = out["w"] * live.astype(out["w"].dtype)
        return out

    def cohort(self, idx, active=None) -> dict:
        """Gather clients ``idx``: staged upload if a matching prefetch is
        in flight, else a synchronous host gather + upload; either way the
        dtype transform and queue mask run in the same traced program the
        resident plane fuses into its gather — so cohorts are bit-for-bit
        across planes."""
        idx = np.asarray(idx, np.int64)
        act = None if active is None else np.asarray(active, np.int64)
        staged = None
        if self._prefetcher is not None:
            staged = self._prefetcher.take(idx, act)
        if staged is None:
            staged = self._upload(self._gather_host(idx))
        else:
            self._uploaded_nbytes = sum(int(v.size) * v.dtype.itemsize
                                        for v in staged.values())
        if act is None:
            return self._finish(staged)
        return self._finish_queued(staged,
                                   self._place(act.astype(np.int32)))


def _key(idx: np.ndarray, active: np.ndarray | None) -> tuple:
    return (idx.tobytes(), None if active is None else active.tobytes())


class CohortPrefetcher:
    """Ring-buffered background staging of upcoming cohort uploads.

    ``start(idx, active)`` hands a *predicted* selection to a daemon
    thread that gathers the rows into one of ``depth + 1`` reusable host
    staging buffers (the ring generalizes double-buffering: a buffer an
    in-flight upload reads is never one a queued prefetch writes) and
    ships them to the device with ``jax.device_put``. Up to ``depth``
    predictions may be in flight at once, consumed strictly in FIFO
    order; starting a ``depth+1``-th evicts the oldest (counted
    cancelled). ``take(idx, active)`` walks the queue from the front:
    non-matching entries ahead of a match are stale predictions and are
    discarded as misses; a matching entry is consumed (hit); an empty
    queue returns ``None`` (the caller gathers synchronously).
    ``cancel()`` discards every queued prediction. ``depth=1`` is
    exactly the historical double-buffered single-slot behavior,
    bit-for-bit. Counters record hits / misses / cancels plus staging vs
    blocked time, so the benchmark can report the hit rate and the
    wall-clock the overlap actually hid.
    """

    def __init__(self, corpus: HostCorpus, depth: int = 1):
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self._corpus = corpus
        self.depth = int(depth)
        self._lock = threading.Lock()
        self._pending: list[tuple] = []   # FIFO of (key, event, holder)
        self._buffers: list[dict | None] = [None] * (self.depth + 1)
        self._ring = 0
        self.hits = 0
        self.misses = 0
        self.cancelled = 0
        self.stage_s = 0.0        # background gather+upload time
        self.wait_s = 0.0         # main-thread time blocked in take()

    @staticmethod
    def empty_stats() -> dict:
        return {"hits": 0, "misses": 0, "cancelled": 0, "hit_rate": 0.0,
                "stage_s": 0.0, "wait_s": 0.0, "overlap_s": 0.0}

    @property
    def staging_nbytes(self) -> int:
        return sum(sum(v.nbytes for v in b.values())
                   for b in self._buffers if b is not None)

    @property
    def inflight_nbytes(self) -> int:
        with self._lock:
            staged = [p[2].get("staged") for p in self._pending]
        return sum(sum(int(v.size) * v.dtype.itemsize for v in s.values())
                   for s in staged if s is not None)

    # ------------------------------------------------------------ staging
    def _staging_buffer(self, idx: np.ndarray) -> dict:
        """The next ring buffer, (re)allocated to the cohort shape.
        Preallocated and reused — the host-pinned-buffer analog on
        backends without explicit pinning."""
        m = len(idx)
        self._ring = (self._ring + 1) % len(self._buffers)
        buf = self._buffers[self._ring]
        shapes = {k: (m,) + v.shape[1:]
                  for k, v in self._corpus._arrays.items()}
        if buf is None or any(buf[k].shape != shapes[k] or
                              buf[k].dtype != v.dtype
                              for k, v in self._corpus._arrays.items()):
            buf = {k: np.empty(shapes[k], v.dtype)
                   for k, v in self._corpus._arrays.items()}
            self._buffers[self._ring] = buf
        return buf

    def _stage(self, idx: np.ndarray, buf: dict, holder: dict,
               done: threading.Event) -> None:
        try:
            t0 = time.perf_counter()
            for k, v in self._corpus._arrays.items():
                np.take(v, idx, axis=0, out=buf[k])
            holder["staged"] = self._corpus._upload(buf)
            holder["stage_s"] = time.perf_counter() - t0
        except BaseException as e:  # surfaced to the consuming thread
            holder["error"] = e
        finally:
            done.set()

    def start(self, idx: np.ndarray, active: np.ndarray | None) -> None:
        with self._lock:
            while len(self._pending) >= self.depth:
                # queue full: the OLDEST prediction is dead either way
                # (depth=1 keeps the historical overwrite semantics)
                self._pending.pop(0)
                self.cancelled += 1
            done = threading.Event()
            holder: dict = {}
            self._pending.append((_key(idx, active), done, holder))
        buf = self._staging_buffer(idx)
        threading.Thread(target=self._stage, args=(idx, buf, holder, done),
                         daemon=True).start()

    # ----------------------------------------------------------- consuming
    def take(self, idx: np.ndarray, active: np.ndarray | None):
        want = _key(idx, active)
        with self._lock:
            pending = None
            while self._pending:
                head = self._pending.pop(0)
                if head[0] == want:
                    pending = head
                    break
                self.misses += 1     # stale prediction ahead of the match
            if pending is None:
                return None
        _, done, holder = pending
        t0 = time.perf_counter()
        done.wait()
        self.wait_s += time.perf_counter() - t0
        if "error" in holder:
            raise holder["error"]
        self.hits += 1
        self.stage_s += holder.get("stage_s", 0.0)
        return holder["staged"]

    def cancel(self) -> None:
        with self._lock:
            self.cancelled += len(self._pending)
            self._pending.clear()

    def stats(self) -> dict:
        total = self.hits + self.misses + self.cancelled
        return {"hits": self.hits, "misses": self.misses,
                "cancelled": self.cancelled,
                "hit_rate": self.hits / max(total, 1),
                "stage_s": self.stage_s, "wait_s": self.wait_s,
                # staging time the main thread did NOT spend blocked:
                # the latency the prefetch overlap actually hid
                "overlap_s": max(self.stage_s - self.wait_s, 0.0)}


# ---------------------------------------------------------- plane wiring

def plane_of(corpus) -> str:
    """"resident" | "streaming" for a constructed corpus of either plane."""
    return getattr(corpus, "plane", "resident")


def estimate_nbytes(data) -> int:
    """Storage-dtype bytes of a stacked dict / either corpus (the "auto"
    residency decision input)."""
    if isinstance(data, (ClientCorpus, HostCorpus)):
        return data.nbytes
    return int(sum(np.asarray(v).size * np.asarray(v).dtype.itemsize
                   for v in dict(data).values()))


def as_data_plane(client_data, plane: str = "auto", *,
                  transform: Normalize | None = None,
                  resident_budget: int = RESIDENT_BUDGET_BYTES):
    """Resolve ``client_data`` onto a data plane — THE wiring point
    ``repro.fl.build`` / ``Server`` / ``launch.train --data-plane`` share.

    ``"resident"`` → :class:`ClientCorpus` (device-resident, the fast
    path when N fits), ``"streaming"`` → :class:`HostCorpus`, ``"auto"``
    → an already-constructed corpus passes through on its own plane; a
    stacked dict goes resident while its storage bytes fit
    ``resident_budget`` and streams past it. Explicit planes *convert*
    a corpus of the other plane (host round-trip) rather than refuse.
    """
    if plane not in PLANES:
        raise ValueError(
            f"unknown data plane {plane!r}; expected one of {PLANES}")
    if plane == "auto":
        if isinstance(client_data, (ClientCorpus, HostCorpus)):
            return client_data
        plane = ("resident"
                 if estimate_nbytes(client_data) <= resident_budget
                 else "streaming")
    if plane == "resident":
        if isinstance(client_data, HostCorpus):
            return ClientCorpus(client_data.as_numpy(),
                                transform=client_data.transform
                                if transform is None else transform)
        return ClientCorpus.from_stacked(client_data, transform=transform)
    return HostCorpus.from_stacked(client_data, transform=transform)
