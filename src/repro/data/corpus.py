"""Device-resident client corpus: the FL data plane lives here.

``ClientCorpus`` holds the stacked per-client arrays (``x:(N,S,...)``,
``y:(N,S)``, ``w:(N,S)`` plus any extra keys) **on device, once, in their
natural dtype** — uint8 for real image ingest, float32 for the synthetic
corpus — and answers the three questions every layer above used to
re-derive per round:

* **data plane** — :meth:`cohort` is a jitted on-device gather along the
  client axis (optionally fused with the dtype :class:`Normalize` and a
  :class:`DataQueue` activity mask), replacing the host-side
  ``{k: v[idx]}`` slice + full-cohort H2D transfer the seed-era ``Server``
  performed every round. Per round, only the ``idx`` (and optional queue
  counts) cross the host→device boundary.
* **control plane** — :meth:`label_histograms` / :meth:`label_entropy` /
  :meth:`sizes` are the per-client stats selectors grouped and ranked on
  (previously recomputed by each selector's ``bind_data`` hook).
* **placement** — :meth:`shard` lays the client axis out over a 1-D
  ``("clients",)`` mesh with a ``NamedSharding`` exactly once; subsequent
  cohort gathers run as SPMD programs over the sharded operand and land
  already distributed for the ``shard_map`` client fan-out. Uneven
  client counts (``N % mesh != 0`` — the paper's N=100 on any realistic
  accelerator count) are a first-class *padded-shard* layout: the client
  axis is padded with zero rows (zero ``w`` ⇒ inert clients, tracked by
  :attr:`client_valid`) up to the next mesh multiple, so every array
  shards ``P("clients")`` instead of silently replicating. The padding
  is data-plane only — :attr:`num_clients`, :meth:`sizes`,
  :meth:`label_histograms`, :meth:`label_entropy` and :meth:`as_numpy`
  all keep reporting the *real* N, global client ids in :meth:`cohort`
  are unchanged (padding appends, so the id map is the identity), and
  :meth:`signature` keys compiled programs on the padded layout.

uint8 images are 4x smaller resident than the float32 corpus they
replace; normalization happens inside the traced gather, so the float32
cohort exists only at |S_t| scale on the accelerator, never at N scale
and never on the host.

``DataQueue`` is the round-indexed subset schedule behind the
entropy-driven dynamic-data-queue selector (arXiv 2410.17792): each
client's *effective* local dataset starts small and grows to the full
shard over training; the corpus applies it as a weight mask inside the
same jitted gather, so schedules never re-materialize data.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

CLIENT_AXIS = "clients"


@dataclass(frozen=True)
class Normalize:
    """On-device dtype policy: ``(x * scale - mean) / std`` in float32.

    The identity transform is ``Normalize()``; real uint8 ingest pairs
    ``scale=1/255`` with per-channel dataset statistics (see
    :func:`repro.data.ingest.cifar10_normalizer`). Applied inside the
    jitted cohort gather — the corpus stays in its storage dtype.
    """
    scale: float = 1.0
    mean: tuple = (0.0,)
    std: tuple = (1.0,)

    def __call__(self, x: jax.Array) -> jax.Array:
        x = x.astype(jnp.float32) * jnp.float32(self.scale)
        mean = jnp.asarray(self.mean, jnp.float32)
        std = jnp.asarray(self.std, jnp.float32)
        return (x - mean) / std


@dataclass(frozen=True)
class DataQueue:
    """Round-indexed per-client effective-dataset schedule.

    ``active(round, sizes)`` maps each client's real sample count to the
    number of samples "released" to it at that round: a fraction ramping
    from ``start_frac`` to 1.0 over ``rounds_to_full`` rounds, either
    continuously (``growth="linear"``) or in ``stages`` discrete steps
    (``growth="staged"`` — the dynamic data queue of arXiv 2410.17792,
    where clients graduate between queue levels). Deterministic in
    (round, sizes): a speculative selector copy reproduces the exact
    schedule, so queue-masked dispatches replay bit-for-bit.
    """
    start_frac: float = 0.25
    rounds_to_full: int = 100
    growth: str = "linear"          # "linear" | "staged"
    stages: int = 4
    min_samples: int = 1

    def __post_init__(self):
        if self.growth not in ("linear", "staged"):
            raise ValueError(
                f"DataQueue growth must be 'linear' or 'staged', "
                f"got {self.growth!r}")

    def frac(self, round_idx: int) -> float:
        t = min(max(round_idx, 0) / max(self.rounds_to_full, 1), 1.0)
        if self.growth == "staged":
            # graduate in `stages` equal steps; final stage is the full set
            step = np.ceil(t * self.stages) / self.stages
            t = float(step)
        return float(self.start_frac + (1.0 - self.start_frac) * t)

    def active(self, round_idx: int, sizes: np.ndarray) -> np.ndarray:
        sizes = np.asarray(sizes, np.int64)
        want = np.ceil(self.frac(round_idx) * sizes).astype(np.int64)
        return np.clip(np.maximum(want, self.min_samples), 0, sizes)


def _as_device(v):
    """Host array -> committed device array, dtype preserved."""
    if isinstance(v, jax.Array):
        return v
    return jnp.asarray(v)


def pad_client_axis(arrays: dict, pad: int) -> dict:
    """Append ``pad`` zero rows to every array's client axis.

    Zero rows (rather than edge repeats) make padded clients provably
    inert: their ``w`` mask is all-zero, so even a stray gather of a
    padded id contributes nothing to any weighted reduction. Real rows
    are untouched — global client ids keep their positions.
    """
    if pad <= 0:
        return dict(arrays)
    return {k: jnp.concatenate(
        [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)], axis=0)
        for k, v in arrays.items()}


class ClientCorpus(Mapping):
    """Stacked client arrays resident on device; see the module docstring.

    Implements ``Mapping`` over its arrays so seed-era call sites that
    treated the corpus as a plain ``{"x": ..., "y": ..., "w": ...}`` dict
    (shape probes, signature keys) keep working unchanged.
    """

    plane = "resident"

    def __init__(self, arrays: dict, *, transform: Normalize | None = None):
        if not arrays:
            raise ValueError("ClientCorpus needs at least one array")
        n = {k: np.shape(v)[0] for k, v in arrays.items()}
        if len(set(n.values())) != 1:
            raise ValueError(f"client axes disagree: {n}")
        self._arrays = {k: _as_device(v) for k, v in arrays.items()}
        self.transform = transform
        self._mesh = None
        self._n = int(next(iter(self._arrays.values())).shape[0])  # real N
        self._pad = 0                   # zero rows appended by shard()
        self._hists: dict = {}          # num_classes (or None) -> (N, C)
        self._sizes: np.ndarray | None = None
        self._gather = jax.jit(self._gather_impl)
        self._gather_queued = jax.jit(self._gather_queued_impl)

    # ------------------------------------------------------- constructors
    @classmethod
    def from_stacked(cls, data, *, transform: Normalize | None = None
                     ) -> "ClientCorpus":
        """Wrap a ``stack_clients``-style dict; identity on a corpus."""
        if isinstance(data, ClientCorpus):
            return data
        return cls(dict(data), transform=transform)

    @classmethod
    def from_parts(cls, x, y, parts, *, batch_multiple: int = 1,
                   transform: Normalize | None = None) -> "ClientCorpus":
        """Partition assignment lists -> stacked, device-resident corpus.

        Unlike ``stack_clients`` (which casts nothing), the stacked ``x``
        keeps ``x.dtype`` — hand in uint8 images and a :class:`Normalize`
        and the resident corpus is 4x smaller than the float32 layout.
        """
        from .partition import stack_clients
        return cls(stack_clients(x, y, parts, batch_multiple),
                   transform=transform)

    # ---------------------------------------------------- Mapping protocol
    def __getitem__(self, key):
        return self._arrays[key]

    def __iter__(self):
        return iter(self._arrays)

    def __len__(self):
        return len(self._arrays)

    # ----------------------------------------------------------- metadata
    @property
    def num_clients(self) -> int:
        """The *real* client count N — control-plane surfaces never see
        the padded rows :meth:`shard` may have appended."""
        return self._n

    @property
    def padded_num_clients(self) -> int:
        """Leading-axis length of the resident arrays (N + shard pad)."""
        return int(next(iter(self._arrays.values())).shape[0])

    @property
    def client_valid(self) -> np.ndarray:
        """(padded_N,) bool — True for real clients, False for pad rows."""
        valid = np.zeros(self.padded_num_clients, bool)
        valid[:self._n] = True
        return valid

    @property
    def samples_per_client(self) -> int:
        return int(self._arrays["y"].shape[1]) if "y" in self._arrays \
            else int(next(iter(self._arrays.values())).shape[1])

    def signature(self) -> tuple:
        """Hashable (key, shape, dtype) + transform + pad tuple for jit
        caches — a padded-shard layout must never be served a program
        compiled for the unpadded (or differently padded) one."""
        return (tuple((k, tuple(v.shape), str(v.dtype))
                      for k, v in sorted(self._arrays.items())),
                self.transform, self._pad)

    @property
    def nbytes(self) -> int:
        """Resident bytes of the stored corpus (storage dtype), summed
        over every device shard (pad rows included)."""
        return int(sum(v.size * v.dtype.itemsize
                       for v in self._arrays.values()))

    def device_nbytes(self) -> int:
        """Max resident bytes of the corpus on any one addressable device.

        Replicated layouts hold the whole corpus per device (== ``nbytes``
        for a single-device or replicated placement); the padded-shard
        layout holds ~``nbytes / mesh`` — the memory win the uneven-mesh
        A/B in benchmarks/dataplane_bench.py measures.
        """
        per: dict = {}
        for v in self._arrays.values():
            for s in v.addressable_shards:
                per[s.device] = per.get(s.device, 0) + int(
                    s.data.size * s.data.dtype.itemsize)
        return max(per.values())

    def cohort_nbytes(self, m: int) -> int:
        """Bytes a host-slice data plane would ship per round for a cohort
        of ``m`` clients — the float32 post-transform layout the seed-era
        server transferred (the corpus path ships only ``idx``)."""
        total = 0
        for k, v in self._arrays.items():
            itemsize = (4 if k == "x" and self.transform is not None
                        else v.dtype.itemsize)
            total += int(np.prod(v.shape[1:], dtype=np.int64)) * itemsize * m
        return total

    def as_numpy(self) -> dict:
        """Host copy of the raw (untransformed) arrays, storage dtype,
        real N rows only (shard pad rows are a placement detail)."""
        return {k: np.asarray(v)[:self._n] for k, v in self._arrays.items()}

    def memory_report(self) -> dict:
        """Plane-aware byte accounting, same schema as the streaming
        plane's (:meth:`repro.data.stream.HostCorpus.memory_report`):
        the resident plane keeps the whole corpus on device and holds no
        host mapping or staging buffers."""
        return {
            "plane": self.plane,
            "host_mapped_bytes": 0,
            "host_is_mmap": False,
            "device_resident_bytes": self.device_nbytes(),
            "staging_nbytes": 0,
            "num_clients": self._n,
        }

    # ------------------------------------------------- control-plane stats
    def sizes(self) -> np.ndarray:
        """Per-client real (unpadded) sample counts, from the w mask."""
        if self._sizes is None:
            if "w" in self._arrays:
                self._sizes = np.asarray(
                    jnp.sum(self._arrays["w"][:self._n], axis=1)
                ).astype(np.int64)
            else:
                self._sizes = np.full(self.num_clients,
                                      self.samples_per_client, np.int64)
        return self._sizes

    def label_histograms(self, num_classes: int | None = None) -> np.ndarray:
        """(N, C) weighted label counts — the grouping/ranking input for
        ``catgroups`` and the ``queue`` selector; computed once per
        ``num_classes``, host-side (control plane), cached. Always real-N
        rows, whatever the resident padding."""
        if num_classes not in self._hists:
            from ..core.pools import label_histograms
            y = np.asarray(self._arrays["y"])[:self._n]
            w = (np.asarray(self._arrays["w"])[:self._n]
                 if "w" in self._arrays else None)
            self._hists[num_classes] = label_histograms(
                y, w, num_classes=num_classes)
        return self._hists[num_classes]

    def label_entropy(self) -> np.ndarray:
        """Per-client Shannon entropy (nats) of the label distribution."""
        from ..core.pools import hist_entropy
        hists = self.label_histograms()
        return np.asarray([hist_entropy(h) for h in hists], np.float64)

    # ------------------------------------------------------------ placement
    def shard(self, mesh, axis: str = CLIENT_AXIS) -> "ClientCorpus":
        """Lay the client axis over ``mesh[axis]`` once (idempotent).

        ``N % mesh[axis] != 0`` is a first-class layout, not a fallback:
        the client axis is padded with zero rows (:func:`pad_client_axis`)
        up to the next mesh multiple, so every array shards ``P(axis)``
        on any mesh size — never replicates. Padding appends, so global
        client ids are unchanged and :meth:`cohort` needs no id remap;
        padded clients carry zero weight and are excluded from every
        control-plane stat (real-N contract). Re-sharding onto a mesh of
        a different size re-derives the pad from the real rows. Returns
        self.
        """
        if self._mesh is mesh:
            return self
        from jax.sharding import NamedSharding, PartitionSpec as P
        size = mesh.shape[axis]
        pad = (-self._n) % size
        if pad != self._pad:
            real = {k: v[:self._n] for k, v in self._arrays.items()}
            self._arrays = pad_client_axis(real, pad)
            self._pad = pad
        sharding = NamedSharding(mesh, P(axis))
        for k, v in self._arrays.items():
            self._arrays[k] = jax.device_put(v, sharding)
        self._mesh = mesh
        return self

    # ------------------------------------------------------------ data plane
    def put_index(self, v) -> jax.Array:
        """Host index vector -> device, replicated over the corpus mesh.

        Once the corpus is mesh-sharded, a single-device ``idx`` would be
        resharded device-to-device inside the jitted gather on every call;
        placing it replicated up front keeps the gather free of implicit
        transfers (and visible as the only H2D payload per round). This is
        how a caller pre-stages ``idx`` to prove the gather transfer-free
        under ``jax.transfer_guard`` on any mesh size."""
        if self._mesh is None:
            return jnp.asarray(v)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(v, NamedSharding(self._mesh, P()))

    def _gather_impl(self, arrays: dict, idx: jax.Array) -> dict:
        out = {k: v[idx] for k, v in arrays.items()}
        if self.transform is not None and "x" in out:
            out["x"] = self.transform(out["x"])
        return out

    def _gather_queued_impl(self, arrays: dict, idx: jax.Array,
                            active: jax.Array) -> dict:
        out = self._gather_impl(arrays, idx)
        if "w" in out:
            s = out["w"].shape[1]
            live = jnp.arange(s)[None, :] < active[:, None]
            out["w"] = out["w"] * live.astype(out["w"].dtype)
        return out

    def traced_cohort(self, idx: jax.Array, active=None) -> dict:
        """The cohort gather as a *traceable* op, for callers composing it
        into a larger jitted program (the scan engine folds R rounds of
        gather + ClientUpdate + judgment into one ``lax.scan``). Same math
        as :meth:`cohort` — ``idx`` must already be a traced/device array;
        the streaming plane deliberately has no such method (its gather is
        host-side), which is how engines detect a foldable data plane."""
        if active is None:
            return self._gather_impl(self._arrays, idx)
        return self._gather_queued_impl(self._arrays, idx, active)

    def cohort(self, idx, active=None) -> dict:
        """Jitted on-device gather of clients ``idx`` along axis 0.

        ``active`` (optional, per-selected-client sample counts from a
        :class:`DataQueue`) masks each client's weight row down to its
        released prefix — inside the same traced program, so a dynamic
        queue costs no extra transfer or copy. Only ``idx`` (and
        ``active``) move host→device; an already-device ``idx`` is used
        as-is, making the gather provably transfer-free (see
        benchmarks/dataplane_bench.py's tripwire). ``idx`` holds *global*
        client ids in ``[0, N)`` — the padded-shard layout appends its pad
        rows, so the id map through the padded operand is the identity
        and the gather stays SPMD on any mesh size.
        """
        if not isinstance(idx, jax.Array):
            idx = self.put_index(np.asarray(idx, np.int32))
        if active is None:
            return self._gather(self._arrays, idx)
        if not isinstance(active, jax.Array):
            active = self.put_index(np.asarray(active, np.int32))
        return self._gather_queued(self._arrays, idx, active)
