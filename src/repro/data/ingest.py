"""Real-image ingest: CIFAR-10 from a local directory, synthetic fallback.

The container is offline, so nothing here downloads. Point
:func:`load_cifar10` at a directory containing the standard python-pickle
release (``cifar-10-batches-py/`` with ``data_batch_1..5`` +
``test_batch``, from ``cifar-10-python.tar.gz`` extracted anywhere under
``root``) and it returns **uint8** HWC images — the natural storage dtype
for :class:`repro.data.corpus.ClientCorpus`, which normalizes on device
at cohort-gather time via :func:`cifar10_normalizer`.

:func:`load_image_corpus` is the single entry the launcher/benchmarks
use: CIFAR-10 when a root is given (missing batches under it fail
loudly), the synthetic class-template dataset when no root is given,
plus the matching ``Normalize`` transform and a ``source`` tag so runs
record what they trained on.
"""
from __future__ import annotations

import os
import pickle
from dataclasses import dataclass

import numpy as np

from .corpus import Normalize
from .synthetic import make_image_dataset

# per-channel statistics of the CIFAR-10 training set (the standard values)
CIFAR10_MEAN = (0.4914, 0.4822, 0.4465)
CIFAR10_STD = (0.2470, 0.2435, 0.2616)

_TRAIN_BATCHES = tuple(f"data_batch_{i}" for i in range(1, 6))
_TEST_BATCH = "test_batch"


def cifar10_normalizer() -> Normalize:
    """uint8 -> float32 on-device policy: /255 then per-channel (x-m)/s."""
    return Normalize(scale=1.0 / 255.0, mean=CIFAR10_MEAN, std=CIFAR10_STD)


def _find_batches_dir(root: str) -> str:
    """Locate the directory holding the pickle batches under ``root``."""
    candidates = [root, os.path.join(root, "cifar-10-batches-py")]
    for cand in candidates:
        if os.path.isfile(os.path.join(cand, _TRAIN_BATCHES[0])):
            return cand
    for dirpath, _, files in os.walk(root):
        if _TRAIN_BATCHES[0] in files:
            return dirpath
    raise FileNotFoundError(
        f"no CIFAR-10 python batches (data_batch_1..5) under {root!r}; "
        "extract cifar-10-python.tar.gz there or pass its directory")


def _read_batch(path: str) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        blob = pickle.load(f, encoding="bytes")
    x = np.asarray(blob[b"data"], np.uint8)          # (n, 3072) CHW-flat
    y = np.asarray(blob[b"labels"], np.int32)
    x = x.reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)   # -> (n, 32, 32, 3)
    return np.ascontiguousarray(x), y


def load_cifar10(root: str):
    """((xtr, ytr), (xte, yte)) — x uint8 (n, 32, 32, 3), y int32."""
    d = _find_batches_dir(root)
    xs, ys = zip(*(_read_batch(os.path.join(d, b)) for b in _TRAIN_BATCHES))
    xtr, ytr = np.concatenate(xs), np.concatenate(ys)
    xte, yte = _read_batch(os.path.join(d, _TEST_BATCH))
    return (xtr, ytr), (xte, yte)


@dataclass(frozen=True)
class ImageCorpusSource:
    """What :func:`load_image_corpus` resolved to."""
    train: tuple          # (x, y) — x in storage dtype (uint8 or float32)
    test: tuple           # (x, y)
    transform: Normalize | None
    source: str           # "cifar10" | "synthetic"
    num_classes: int


def load_image_corpus(root: str | None = None, *, num_classes: int = 10,
                      train_per_class: int = 500, test_per_class: int = 100,
                      hw: int = 16, noise: float = 0.9,
                      seed: int = 0) -> ImageCorpusSource:
    """CIFAR-10 from ``root``; synthetic when no ``root`` is given.

    A non-empty ``root`` MUST hold the pickle batches — a missing or
    not-yet-populated directory raises ``FileNotFoundError`` rather than
    silently training on synthetic data. The synthetic keyword set
    mirrors ``make_image_dataset`` (reduced scale by default); CIFAR-10
    ignores those knobs and returns the full 50k/10k uint8 set with the
    on-device normalizer attached.
    """
    if root:
        (xtr, ytr), (xte, yte) = load_cifar10(root)
        return ImageCorpusSource((xtr, ytr), (xte, yte),
                                 cifar10_normalizer(), "cifar10", 10)
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=num_classes, train_per_class=train_per_class,
        test_per_class=test_per_class, hw=hw, noise=noise, seed=seed)
    return ImageCorpusSource((xtr, ytr), (xte, yte), None, "synthetic",
                             num_classes)
