"""Real-image ingest: CIFAR-10/100 and CINIC-10 from disk, synthetic
fallback.

The container is offline, so nothing here downloads. Three on-disk
formats share one surface:

* :func:`load_cifar10`  — the standard python-pickle release
  (``cifar-10-batches-py/`` with ``data_batch_1..5`` + ``test_batch``).
* :func:`load_cifar100` — the same pickle format's 100-class release
  (``cifar-100-python/`` with ``train`` + ``test`` files, fine labels).
* :func:`load_cinic10`  — the CINIC-10 directory layout
  (``train/<class>/*.png`` + ``test/<class>/*.png``; per-class ``.npy``
  stacks are also accepted so tests and PIL-less environments work).

All return **uint8** HWC images — the natural storage dtype for
:class:`repro.data.corpus.ClientCorpus`, which normalizes on device at
cohort-gather time via the matching ``*_normalizer()``.

:func:`load_image_corpus` is the single entry the launcher/benchmarks
use: it auto-detects which of the three layouts lives under ``root``
(or takes ``dataset=`` explicitly), fails loudly on an empty root, and
falls back to the synthetic class-template dataset when no root is
given, attaching the right ``Normalize`` transform and a ``source`` tag
so runs record what they trained on. The first real load writes a
packed ``.npy`` cache next to the dataset
(``<root>/repro-packed/<name>/``); repeated runs memory-map it
(``np.load(mmap_mode="r")``) instead of re-parsing pickles/PNGs, and
:class:`repro.data.stream.HostCorpus` can map the same files directly.
"""
from __future__ import annotations

import json
import os
import pickle
from dataclasses import dataclass

import numpy as np

from .corpus import Normalize
from .synthetic import make_image_dataset

# per-channel training-set statistics (the standard published values)
CIFAR10_MEAN = (0.4914, 0.4822, 0.4465)
CIFAR10_STD = (0.2470, 0.2435, 0.2616)
CIFAR100_MEAN = (0.5071, 0.4865, 0.4409)
CIFAR100_STD = (0.2673, 0.2564, 0.2762)
CINIC10_MEAN = (0.47889522, 0.47227842, 0.43047404)
CINIC10_STD = (0.24205776, 0.23828046, 0.25874835)

_TRAIN_BATCHES = tuple(f"data_batch_{i}" for i in range(1, 6))
_TEST_BATCH = "test_batch"
_CINIC_PARTS = ("train", "test")


def cifar10_normalizer() -> Normalize:
    """uint8 -> float32 on-device policy: /255 then per-channel (x-m)/s."""
    return Normalize(scale=1.0 / 255.0, mean=CIFAR10_MEAN, std=CIFAR10_STD)


def cifar100_normalizer() -> Normalize:
    return Normalize(scale=1.0 / 255.0, mean=CIFAR100_MEAN,
                     std=CIFAR100_STD)


def cinic10_normalizer() -> Normalize:
    return Normalize(scale=1.0 / 255.0, mean=CINIC10_MEAN, std=CINIC10_STD)


def _find_file_dir(root: str, marker: str, subdir: str, hint: str) -> str:
    """Locate the directory holding pickle file ``marker`` under ``root``."""
    for cand in (root, os.path.join(root, subdir)):
        if os.path.isfile(os.path.join(cand, marker)):
            return cand
    for dirpath, _, files in os.walk(root):
        if marker in files:
            return dirpath
    raise FileNotFoundError(f"no {hint} under {root!r}")


def _read_batch(path: str, label_key: bytes = b"labels"
                ) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        blob = pickle.load(f, encoding="bytes")
    x = np.asarray(blob[b"data"], np.uint8)          # (n, 3072) CHW-flat
    y = np.asarray(blob[label_key], np.int32)
    x = x.reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)   # -> (n, 32, 32, 3)
    return np.ascontiguousarray(x), y


def load_cifar10(root: str):
    """((xtr, ytr), (xte, yte)) — x uint8 (n, 32, 32, 3), y int32."""
    d = _find_file_dir(
        root, _TRAIN_BATCHES[0], "cifar-10-batches-py",
        "CIFAR-10 python batches (data_batch_1..5); extract "
        "cifar-10-python.tar.gz there or pass its directory")
    xs, ys = zip(*(_read_batch(os.path.join(d, b)) for b in _TRAIN_BATCHES))
    xtr, ytr = np.concatenate(xs), np.concatenate(ys)
    xte, yte = _read_batch(os.path.join(d, _TEST_BATCH))
    return (xtr, ytr), (xte, yte)


def load_cifar100(root: str):
    """CIFAR-100 python release: same pickle format, ``train``/``test``
    files, 100 *fine* labels. Returns ((xtr, ytr), (xte, yte)) uint8."""
    d = _find_file_dir(
        root, "train", "cifar-100-python",
        "CIFAR-100 python release (train/test pickles); extract "
        "cifar-100-python.tar.gz there or pass its directory")
    xtr, ytr = _read_batch(os.path.join(d, "train"), b"fine_labels")
    xte, yte = _read_batch(os.path.join(d, "test"), b"fine_labels")
    return (xtr, ytr), (xte, yte)


def _load_image_file(path: str) -> np.ndarray:
    try:
        from PIL import Image
    except ImportError:  # pragma: no cover — PIL ships in the dev env
        raise RuntimeError(
            f"reading {path!r} needs Pillow, which is not installed — "
            "provide per-class .npy stacks instead (any (n, h, w, 3) "
            "uint8 array per class directory)") from None
    with Image.open(path) as im:
        return np.asarray(im.convert("RGB"), np.uint8)


def _read_class_dir(cdir: str) -> np.ndarray:
    """All images in one class directory: .npy stacks and/or png/jpeg."""
    xs = []
    for fname in sorted(os.listdir(cdir)):
        ext = fname.lower().rsplit(".", 1)[-1]
        path = os.path.join(cdir, fname)
        if ext == "npy":
            arr = np.asarray(np.load(path), np.uint8)
            xs.append(arr if arr.ndim == 4 else arr[None])
        elif ext in ("png", "jpg", "jpeg"):
            xs.append(_load_image_file(path)[None])
    if not xs:
        raise FileNotFoundError(f"no .npy/.png/.jpeg images in {cdir!r}")
    return np.concatenate(xs)


def _find_cinic_dir(root: str) -> str:
    for cand in (root, os.path.join(root, "CINIC-10"),
                 os.path.join(root, "cinic-10")):
        if all(os.path.isdir(os.path.join(cand, p)) for p in _CINIC_PARTS):
            return cand
    for dirpath, dirs, _ in os.walk(root):
        if all(p in dirs for p in _CINIC_PARTS):
            return dirpath
    raise FileNotFoundError(
        f"no CINIC-10 layout (train/ + test/ class directories) under "
        f"{root!r}")


def load_cinic10(root: str):
    """CINIC-10 directory layout: ``train/<class>/`` + ``test/<class>/``
    holding png/jpeg images or ``.npy`` stacks. Class indices follow the
    sorted class-directory names — for the real CINIC-10 that is the
    CIFAR-10 label order, which is alphabetical. Returns
    ((xtr, ytr), (xte, yte)) uint8 HWC; the ``valid/`` split, when
    present, is deliberately left out (fold it into ``train/`` on disk to
    use it)."""
    d = _find_cinic_dir(root)

    def part(name: str):
        pdir = os.path.join(d, name)
        classes = sorted(c for c in os.listdir(pdir)
                         if os.path.isdir(os.path.join(pdir, c)))
        if not classes:
            raise FileNotFoundError(f"no class directories in {pdir!r}")
        xs, ys = [], []
        for ci, cname in enumerate(classes):
            x = _read_class_dir(os.path.join(pdir, cname))
            xs.append(x)
            ys.append(np.full(x.shape[0], ci, np.int32))
        return np.concatenate(xs), np.concatenate(ys)

    return part("train"), part("test")


# loader, normalizer factory, class count — keyed by dataset name
_DATASETS = {
    "cifar10": (load_cifar10, cifar10_normalizer, 10),
    "cifar100": (load_cifar100, cifar100_normalizer, 100),
    "cinic10": (load_cinic10, cinic10_normalizer, 10),
}

# ------------------------------------------------------- packed .npy cache
# First real load of a dataset writes its splits as plain .npy files next
# to the dataset (``<root>/repro-packed/<name>/``); every later load
# reopens them with ``np.load(mmap_mode="r")`` — no pickle/PNG parsing,
# no host copy of the full set, and exactly the layout
# ``repro.data.stream.HostCorpus`` memory-maps directly.

_PACKED_DIRNAME = "repro-packed"
_SPLIT_KEYS = ("x_train", "y_train", "x_test", "y_test")


def packed_cache_dir(root: str, name: str) -> str:
    """Where :func:`load_image_corpus` packs dataset ``name`` under
    ``root``."""
    return os.path.join(root, _PACKED_DIRNAME, name)


def load_packed(cache_dir: str):
    """Memory-mapped ``((xtr, ytr), (xte, yte))`` from a packed cache
    directory, or None when absent/incomplete (corrupt caches fall back
    to the real loader rather than fail the run)."""
    if not os.path.isfile(os.path.join(cache_dir, "meta.json")):
        return None
    try:
        a = [np.load(os.path.join(cache_dir, f"{k}.npy"), mmap_mode="r")
             for k in _SPLIT_KEYS]
    except (OSError, ValueError):  # pragma: no cover — corrupt cache
        return None
    return (a[0], a[1]), (a[2], a[3])


def write_packed(cache_dir: str, name: str, train: tuple,
                 test: tuple) -> None:
    """Pack the loaded splits; meta.json lands last so a partial write
    never looks like a complete cache."""
    os.makedirs(cache_dir, exist_ok=True)
    for k, v in zip(_SPLIT_KEYS, (*train, *test)):
        np.save(os.path.join(cache_dir, f"{k}.npy"), np.ascontiguousarray(v))
    with open(os.path.join(cache_dir, "meta.json"), "w") as f:
        json.dump({"dataset": name, "keys": list(_SPLIT_KEYS)}, f)


def _detect_packed(root: str) -> str | None:
    """Dataset name of a packed cache under ``root``, if one exists —
    lets auto-detection skip the raw-layout probe entirely."""
    base = os.path.join(root, _PACKED_DIRNAME)
    if not os.path.isdir(base):
        return None
    for name in sorted(os.listdir(base)):
        if name in _DATASETS and os.path.isfile(
                os.path.join(base, name, "meta.json")):
            return name
    return None


def _detect_dataset(root: str) -> str:
    """Which of the three on-disk layouts lives under ``root``."""
    for name, probe in (
            ("cifar10", lambda: _find_file_dir(
                root, _TRAIN_BATCHES[0], "cifar-10-batches-py", "x")),
            ("cifar100", lambda: _find_file_dir(
                root, "train", "cifar-100-python", "x")),
            ("cinic10", lambda: _find_cinic_dir(root))):
        try:
            probe()
            return name
        except FileNotFoundError:
            continue
    raise FileNotFoundError(
        f"no CIFAR-10 batches, CIFAR-100 pickles, or CINIC-10 class "
        f"directories under {root!r}; extract a release there or pass "
        "dataset= explicitly")


@dataclass(frozen=True)
class ImageCorpusSource:
    """What :func:`load_image_corpus` resolved to."""
    train: tuple          # (x, y) — x in storage dtype (uint8 or float32)
    test: tuple           # (x, y)
    transform: Normalize | None
    source: str           # "cifar10" | "cifar100" | "cinic10" | "synthetic"
    num_classes: int


def load_image_corpus(root: str | None = None, *, dataset: str = "auto",
                      cache: bool = True,
                      num_classes: int = 10,
                      train_per_class: int = 500, test_per_class: int = 100,
                      hw: int = 16, noise: float = 0.9,
                      seed: int = 0) -> ImageCorpusSource:
    """Real images from ``root``; synthetic when no ``root`` is given.

    A non-empty ``root`` MUST hold one of the known layouts —
    ``dataset="auto"`` (default) probes a packed cache first, then
    CIFAR-10, then CIFAR-100, then CINIC-10, and a missing or
    not-yet-populated directory raises ``FileNotFoundError`` rather than
    silently training on synthetic data. With ``cache=True`` (default)
    the first real load writes packed ``.npy`` splits under
    ``<root>/repro-packed/<dataset>/`` and later loads reopen them with
    ``np.load(mmap_mode="r")`` — skipping pickle/PNG parsing and giving
    the streaming data plane a host store it can map without a copy.
    The synthetic keyword set mirrors ``make_image_dataset`` (reduced
    scale by default); the real datasets ignore those knobs and return
    the full uint8 set with the on-device normalizer attached.
    """
    if root:
        if dataset == "auto":
            name = ((_detect_packed(root) if cache else None)
                    or _detect_dataset(root))
        else:
            name = dataset
        if name not in _DATASETS:
            raise ValueError(
                f"unknown dataset {dataset!r}; expected one of "
                f"{('auto', *sorted(_DATASETS))}")
        loader, normalizer, ncls = _DATASETS[name]
        packed = load_packed(packed_cache_dir(root, name)) if cache \
            else None
        if packed is not None:
            (xtr, ytr), (xte, yte) = packed
        else:
            (xtr, ytr), (xte, yte) = loader(root)
            if cache:
                try:
                    write_packed(packed_cache_dir(root, name), name,
                                 (xtr, ytr), (xte, yte))
                except OSError:  # read-only dataset mounts are fine
                    pass
        return ImageCorpusSource((xtr, ytr), (xte, yte), normalizer(),
                                 name, ncls)
    if dataset != "auto":
        raise ValueError(
            f"dataset={dataset!r} needs a root directory; the synthetic "
            "fallback only runs with dataset='auto'")
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=num_classes, train_per_class=train_per_class,
        test_per_class=test_per_class, hw=hw, noise=noise, seed=seed)
    return ImageCorpusSource((xtr, ytr), (xte, yte), None, "synthetic",
                             num_classes)
