"""Synthetic datasets (the container is offline — no CIFAR/CINIC download).

``make_image_dataset`` builds a class-conditional image dataset whose
difficulty is controllable: each class c gets a random low-frequency
template; samples are template + per-sample Gaussian noise + random global
brightness/contrast jitter. With the default noise the paper's LeNet-scale
CNN reaches neither 0% nor 100% in a few rounds — the regime where the FL
methods separate, which is what the §Repro tables need.

``make_token_dataset`` builds a synthetic LM corpus with per-class Zipfian
token distributions (classes = latent "domains"), used for FL fine-tuning
examples of the assigned LM architectures.
"""
from __future__ import annotations

import numpy as np


def make_image_dataset(
    num_classes: int = 10,
    train_per_class: int = 500,
    test_per_class: int = 100,
    hw: int = 16,
    channels: int = 3,
    noise: float = 0.9,
    seed: int = 0,
    template_seed: int = 1234,
):
    """Class templates are ORTHONORMAL low-frequency patterns drawn from a
    fixed ``template_seed``, so the Bayes difficulty is identical across
    ``seed`` (which only varies sampling/noise/partition) — otherwise
    seed-to-seed template geometry dominates method differences."""
    rng = np.random.default_rng(seed)
    t_rng = np.random.default_rng(template_seed)
    low = t_rng.normal(size=(num_classes, 4 * 4 * channels))
    q, _ = np.linalg.qr(low.T)                   # orthonormal columns
    low = (q.T[:num_classes] * np.sqrt(4 * 4 * channels)).reshape(
        num_classes, 4, 4, channels)
    reps = hw // 4
    templates = np.repeat(np.repeat(low, reps, axis=1), reps, axis=2)

    def sample(n_per_class, rng):
        xs, ys = [], []
        for c in range(num_classes):
            base = templates[c][None]
            x = base + noise * rng.normal(
                size=(n_per_class, hw, hw, channels))
            # global jitter (brightness/contrast) to break trivial cues
            bright = rng.normal(scale=0.2, size=(n_per_class, 1, 1, 1))
            x = x * (1 + bright) + 0.1 * rng.normal(
                size=(n_per_class, 1, 1, 1))
            xs.append(x)
            ys.append(np.full(n_per_class, c, np.int32))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]

    xtr, ytr = sample(train_per_class, rng)
    xte, yte = sample(test_per_class, np.random.default_rng(seed + 1))
    return (xtr, ytr), (xte, yte)


def make_token_dataset(
    vocab_size: int = 1024,
    num_domains: int = 8,
    docs_per_domain: int = 64,
    seq_len: int = 128,
    seed: int = 0,
):
    """Per-domain Zipf token streams; labels are next tokens (LM)."""
    rng = np.random.default_rng(seed)
    xs, ds = [], []
    for d in range(num_domains):
        # domain-specific permutation of a Zipf distribution
        ranks = rng.permutation(vocab_size)
        p = 1.0 / (1.0 + np.arange(vocab_size, dtype=np.float64)) ** 1.2
        p /= p.sum()
        probs = np.empty(vocab_size)
        probs[ranks] = p
        toks = rng.choice(vocab_size, size=(docs_per_domain, seq_len + 1),
                          p=probs)
        xs.append(toks)
        ds.append(np.full(docs_per_domain, d, np.int32))
    x = np.concatenate(xs).astype(np.int32)
    dom = np.concatenate(ds)
    perm = rng.permutation(len(dom))
    return x[perm], dom[perm]
