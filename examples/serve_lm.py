"""Batched serving of a (reduced) assigned architecture: prefill a prompt
batch, decode with the position-tagged KV / SSM-state cache — the same
serve steps the multi-pod dry-run lowers at production shapes.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b --gen 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding window (ring-buffer cache)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced().replace(
        remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    extra = cfg.num_patches if cfg.family == "vlm" else 0
    w = args.window or None
    t0 = time.time()
    logits, cache = jax.jit(lambda p, bt: model.prefill(
        p, bt, window=w, cache_len=s + extra + args.gen))(params, batch)
    print(f"prefill {b}x{s}: {time.time() - t0:.2f}s  "
          f"logits {logits.shape}")

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, window=w))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        toks.append(tok)
    print(f"decode {args.gen - 1} steps: {time.time() - t0:.2f}s")
    print("generated:", np.asarray(jnp.concatenate(toks, 1))[0].tolist())


if __name__ == "__main__":
    main()
