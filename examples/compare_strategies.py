"""Paper Table 3 in miniature: every FL optimizer, with and without
FedEntropy's device grouping, on the same non-IID split.

  PYTHONPATH=src python examples/compare_strategies.py
"""
import jax
import jax.numpy as jnp

from repro.core.simulator import FedEntropyTrainer, FLConfig
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 6


def main():
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=4, train_per_class=80, test_per_class=20, hw=16,
        noise=0.4, seed=1)
    parts = partition("case1", ytr, 10, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    test = (jnp.asarray(xte), jnp.asarray(yte))

    print(f"{'strategy':10s} {'plain':>8s} {'+fedentropy':>12s}")
    for strat in ("fedavg", "fedprox", "scaffold", "moon"):
        accs = []
        for judge in (False, True):
            tr = FedEntropyTrainer(
                cnn.apply, params, data,
                FLConfig(num_clients=10, participation=0.4,
                         use_judgment=judge, use_pools=judge, seed=0),
                LocalSpec(strategy=strat, epochs=2, batch_size=20, lr=0.02))
            for _ in range(ROUNDS):
                tr.round()
            accs.append(tr.evaluate(*test)["accuracy"])
        print(f"{strat:10s} {accs[0]:8.3f} {accs[1]:12.3f}")


if __name__ == "__main__":
    main()
