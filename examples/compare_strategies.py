"""Paper Table 3 in miniature: every FL optimizer, with and without
FedEntropy's device grouping, on the same non-IID split.

With the pluggable ``repro.fl`` API the "+fedentropy" column is a
two-keyword override of the plain composition: swap the selector to the
epsilon-greedy pools and the judge to maximum entropy — the local update
rule is untouched (the paper's orthogonality argument, Sec. 3.4).

  PYTHONPATH=src python examples/compare_strategies.py
"""
import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

ROUNDS = 6


def main():
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=4, train_per_class=80, test_per_class=20, hw=16,
        noise=0.4, seed=1)
    parts = partition("case1", ytr, 10, 4, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=4)
    test = (jnp.asarray(xte), jnp.asarray(yte))

    print(f"{'strategy':10s} {'plain':>8s} {'+fedentropy':>12s}")
    for strat in ("fedavg", "fedprox", "scaffold", "moon"):
        accs = []
        for overrides in ({}, {"selector": "pools", "judge": "maxent"}):
            server = fl.build(
                strat, cnn.apply, params, data,
                fl.ServerConfig(num_clients=10, participation=0.4, seed=0),
                fl.LocalSpec(epochs=2, batch_size=20, lr=0.02),
                **overrides)
            server.fit(ROUNDS)
            accs.append(server.evaluate(*test)["accuracy"])
        print(f"{strat:10s} {accs[0]:8.3f} {accs[1]:12.3f}")


if __name__ == "__main__":
    main()
