"""FL fine-tuning of an assigned LM architecture with gradient-level
FedEntropy — the mesh-scale formulation (DESIGN.md §2.2) on CPU devices.

Eight logical clients with domain-skewed token data feed four mesh client
slots per round; the in-step judgment masks gradient contributions; the
epsilon-greedy pools steer selection across rounds. Works with any
``--arch`` from the registry (reduced variants).

  PYTHONPATH=src python examples/fl_llm_finetune.py --arch mamba2-130m
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.distributed import FedSpec, make_train_step
from repro.core.pools import DevicePools
from repro.data.synthetic import make_token_dataset
from repro.models.api import build_model
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced().replace(
        remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    m, per, seq = 4, 2, 64
    logical = 8

    corpus, dom = make_token_dataset(
        vocab_size=min(cfg.vocab_size, 512), num_domains=logical,
        docs_per_domain=48, seq_len=seq)

    fed = FedSpec(num_clients=m)
    opt = sgd(lr=0.05, momentum=0.5)
    step = jax.jit(make_train_step(model, opt, fed), donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pools = DevicePools(logical, eps=0.8, seed=0)
    rng = np.random.default_rng(0)

    for it in range(args.rounds):
        sel = pools.select(m)
        rows = [corpus[rng.choice(np.where(dom == c % logical)[0], per)]
                for c in sel]
        batch = {"tokens": jnp.asarray(
            np.concatenate(rows)[:, :seq], jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (m * per, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (m * per, cfg.encoder_seq, cfg.d_model), jnp.float32)
        params, opt_state, metrics = step(params, opt_state, batch)
        mask = np.asarray(metrics["mask"])
        pools.update([sel[i] for i in range(m) if mask[i] > 0],
                     [sel[i] for i in range(m) if mask[i] == 0])
        print(f"round {it}: loss={float(metrics['loss']):.4f} "
              f"positives={int(metrics['num_positive'])}/{m} "
              f"entropy={float(metrics['entropy']):.3f}")
    print("pools:", pools.stats())


if __name__ == "__main__":
    main()
