"""FL fine-tuning of an assigned LM architecture through the registry's
scan engine — the weights-level paper loop (Alg. 2, E local epochs) at
LM scale, R rounds per jitted program.

The composition is ``fedentropy`` with its two LM-scale swaps:

* ``selector="pools-traced"`` — the paper's eps-greedy pools on a
  ``jax.random`` stream, so the pool draw/re-file folds INTO the scan as
  a device-resident carry (no R=1 fallback; the script asserts it);
* ``ScanConfig(params_mode="remat")`` — the scan stacks only soft
  labels/verdicts/cohorts, O(cohort x vocab) per round instead of R
  copies of the LM pytree; mismatched rounds rematerialize their rewind
  point by replaying the confirmed prefix.

The client rule is ``strategy="lmstep"``: every next-token position of
an (S, L+1) token window trains (minibatch SGD + momentum), and the
soft label is the weighted mean next-token distribution (paper Eq. 2,
LM analog). ``--verify`` re-runs the same composition on the sequential
``Server`` and asserts histories match record-for-record — the scan is
an execution strategy, not a different algorithm. ``--kernels pallas``
routes attention through the Pallas flash kernels inside the traced
client update.

  PYTHONPATH=src python examples/fl_llm_finetune.py --arch mamba2-130m
  PYTHONPATH=src python examples/fl_llm_finetune.py --rounds 8 --verify
"""
import argparse
import time

import jax
import numpy as np

import repro.fl as fl
from repro.configs import ARCHS
from repro.data.synthetic import make_token_dataset
from repro.kernels import ops as kops
from repro.launch.train import lm_window_apply, stack_lm_clients
from repro.models.api import build_model


def build_setup(args):
    cfg = ARCHS[args.arch].reduced().replace(
        remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    logical, samples, seq = 8, 8, args.seq_len

    corpus, dom = make_token_dataset(
        vocab_size=min(cfg.vocab_size, 512), num_domains=logical,
        docs_per_domain=48, seq_len=seq)
    client_idx = [np.where(dom == c % logical)[0] for c in range(logical)]
    data = stack_lm_clients(corpus, client_idx, samples, seq, seed=0)

    config = fl.ServerConfig(num_clients=logical, participation=0.5,
                             eps=0.8, seed=0)
    local = fl.LocalSpec(lr=0.05, momentum=0.5, epochs=1, batch_size=4)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, data, config, local, params


def build_server(args, setup, *, engine, runtime=None):
    cfg, model, data, config, local, params = setup
    return fl.build("fedentropy", lm_window_apply(model, cfg), params,
                    data, config, local, selector="pools-traced",
                    strategy="lmstep", engine=engine, runtime=runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--rounds-per-scan", type=int, default=4)
    ap.add_argument("--params-mode", default="remat",
                    choices=["stack", "remat"])
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--kernels", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--verify", action="store_true",
                    help="also run the sequential Server and assert "
                         "histories match record-for-record")
    args = ap.parse_args()
    kops.set_default_backend(args.kernels)

    setup = build_setup(args)
    server = build_server(
        args, setup, engine="scan",
        runtime=fl.ScanConfig(rounds_per_scan=args.rounds_per_scan,
                              params_mode=args.params_mode))
    R = server.scan_rounds()
    assert R == args.rounds_per_scan, (
        f"scan fell back to sequential rounds: {server.fallback_reasons}")
    ys_bytes = server.stacked_ys_nbytes(R)
    print(f"scan: R={R} params_mode={args.params_mode} "
          f"stacked-ys={ys_bytes}B "
          f"({sorted(server.block_ys_shapes(R))} stacked)")

    t0 = time.time()
    for it in range(args.rounds):
        rec = server.round()
        print(f"round {it}: positives={len(rec['positive'])}/"
              f"{len(rec['selected'])} entropy={rec['entropy']:.3f} "
              f"spec={'hit' if rec['spec_hit'] else 'miss'}")
    dt = time.time() - t0
    s = server.stats()
    print(f"done: {args.rounds} rounds in {dt:.1f}s "
          f"({dt / args.rounds:.2f}s/round); blocks={s['blocks']} "
          f"mismatch_rounds={s['mismatch_rounds']} "
          f"selector={s['selector']}")

    if args.verify:
        seq_server = build_server(args, setup, engine="sequential")
        for _ in range(args.rounds):
            seq_server.round()
        for a, b in zip(server.history, seq_server.history):
            for k in ("round", "selected", "positive", "negative",
                      "entropy"):
                assert a[k] == b[k], (a, b)
        leaves = zip(jax.tree.leaves(server.global_params),
                     jax.tree.leaves(seq_server.global_params))
        assert all(bool((np.asarray(x) == np.asarray(y)).all())
                   for x, y in leaves)
        print(f"verify: {args.rounds} scan rounds == sequential Server "
              "(histories and params bit-for-bit)")


if __name__ == "__main__":
    main()
