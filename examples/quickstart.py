"""Quickstart: FedEntropy on the paper's CNN in ~60 seconds on CPU.

Reproduces the paper's core loop (Alg. 2) at toy scale through the
pluggable ``repro.fl`` API: ``build("fedentropy", ...)`` composes
epsilon-greedy pools + maximum-entropy judgment + weighted aggregation,
``build("fedavg", ...)`` the uniform/admit-all baseline. Prints the
per-round positive/negative split and the accuracy trajectory.

Client data rides in a device-resident ``ClientCorpus`` (uint8 storage +
on-device normalization when pointed at a real CIFAR-10 directory):

  PYTHONPATH=src python examples/quickstart.py [path/to/cifar-10-batches-py]
"""
import sys

import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.data import ClientCorpus, load_image_corpus
from repro.data.partition import partition
from repro.models import cnn

NUM_CLIENTS, CLASSES, ROUNDS = 12, 4, 8


def main():
    src = load_image_corpus(sys.argv[1] if len(sys.argv) > 1 else None,
                            num_classes=CLASSES, train_per_class=100,
                            test_per_class=25, hw=16, noise=0.6, seed=3)
    (xtr, ytr), (xte, yte) = src.train, src.test
    parts = partition("case1", ytr, NUM_CLIENTS, src.num_classes, seed=0)
    # storage dtype (uint8 for CIFAR-10) stays resident; normalization
    # happens on device inside the per-round cohort gather
    corpus = ClientCorpus.from_parts(xtr, ytr, parts, batch_multiple=25,
                                     transform=src.transform)
    print(f"corpus: {src.source}, {corpus.num_clients} clients, "
          f"{corpus['x'].dtype} resident, {corpus.nbytes / 1e6:.1f} MB")
    params = cnn.init(jax.random.PRNGKey(0), image_hw=xtr.shape[1],
                      num_classes=src.num_classes)
    xte = jnp.asarray(xte)
    if src.transform is not None:
        xte = src.transform(xte)
    test = (xte, jnp.asarray(yte))

    results = {}
    for name, method in [("FedEntropy", "fedentropy"), ("FedAvg", "fedavg")]:
        server = fl.build(
            method, cnn.apply, params, corpus,
            fl.ServerConfig(num_clients=NUM_CLIENTS, participation=0.34,
                            seed=0),
            fl.LocalSpec(epochs=2, batch_size=25, lr=0.02))
        print(f"== {name} ==")
        for r in range(ROUNDS):
            rec = server.round()
            acc = server.evaluate(*test)["accuracy"]
            print(f"  round {r}: positives={len(rec['positive'])}/"
                  f"{len(rec['selected'])} entropy={rec['entropy']:.3f} "
                  f"acc={acc:.3f} "
                  f"uplink_savings={rec['comm']['savings_fraction']:.0%}")
        results[name] = acc
    print(f"\nfinal: FedEntropy={results['FedEntropy']:.3f} "
          f"vs FedAvg={results['FedAvg']:.3f}")


if __name__ == "__main__":
    main()
