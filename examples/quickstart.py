"""Quickstart: FedEntropy on the paper's CNN in ~60 seconds on CPU.

Reproduces the paper's core loop (Alg. 2) at toy scale through the
pluggable ``repro.fl`` API: ``build("fedentropy", ...)`` composes
epsilon-greedy pools + maximum-entropy judgment + weighted aggregation,
``build("fedavg", ...)`` the uniform/admit-all baseline. Prints the
per-round positive/negative split and the accuracy trajectory.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.fl as fl
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

NUM_CLIENTS, CLASSES, ROUNDS = 12, 4, 8


def main():
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=CLASSES, train_per_class=100, test_per_class=25,
        hw=16, noise=0.6, seed=3)
    parts = partition("case1", ytr, NUM_CLIENTS, CLASSES, seed=0)
    data = stack_clients(xtr, ytr, parts, batch_multiple=25)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16,
                      num_classes=CLASSES)
    test = (jnp.asarray(xte), jnp.asarray(yte))

    results = {}
    for name, method in [("FedEntropy", "fedentropy"), ("FedAvg", "fedavg")]:
        server = fl.build(
            method, cnn.apply, params, data,
            fl.ServerConfig(num_clients=NUM_CLIENTS, participation=0.34,
                            seed=0),
            fl.LocalSpec(epochs=2, batch_size=25, lr=0.02))
        print(f"== {name} ==")
        for r in range(ROUNDS):
            rec = server.round()
            acc = server.evaluate(*test)["accuracy"]
            print(f"  round {r}: positives={len(rec['positive'])}/"
                  f"{len(rec['selected'])} entropy={rec['entropy']:.3f} "
                  f"acc={acc:.3f} "
                  f"uplink_savings={rec['comm']['savings_fraction']:.0%}")
        results[name] = acc
    print(f"\nfinal: FedEntropy={results['FedEntropy']:.3f} "
          f"vs FedAvg={results['FedAvg']:.3f}")


if __name__ == "__main__":
    main()
