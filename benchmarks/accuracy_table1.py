"""Paper Table 1: test accuracy across heterogeneity cases.

Reduced-scale reproduction: FedAvg / FedProx / SCAFFOLD / Moon vs
FedEntropy (= FedAvg + judgment + pools) on case1/case2/case3 synthetic
non-IID splits, mean +- std over seeds. Validated claim: FedEntropy's
accuracy is highest (or tied within noise) in the strongly non-IID cases,
with the biggest margin in case 1 — matching the paper's pattern.
"""
from __future__ import annotations

import time

from .common import SEEDS, compile_cache_summary, mean_std, run_method

CASES = ("case1", "case2", "case3")
BASELINES = ("fedavg", "fedprox", "scaffold", "moon")


def run(fast: bool = False):
    seeds = SEEDS[:1] if fast else SEEDS
    rounds = 15 if fast else 60
    rows, blob = [], {"cases": {}}
    for case in CASES:
        accs: dict[str, list[float]] = {}
        t0 = time.time()
        for seed in seeds:
            for meth in BASELINES + ("fedentropy",):
                r = run_method(case, seed, method=meth,
                               rounds=rounds, eval_every=0)
                accs.setdefault(meth, []).append(r["final_accuracy"])
        dt = (time.time() - t0) * 1e6 / (len(seeds) * 5 * rounds)
        stats = {m: mean_std(v) for m, v in accs.items()}
        blob["cases"][case] = stats
        best_base = max(stats[m][0] for m in BASELINES)
        delta = stats["fedentropy"][0] - best_base
        rows.append((f"table1_{case}", f"{dt:.0f}",
                     f"fedentropy={stats['fedentropy'][0]:.3f}"
                     f"|best_baseline={best_base:.3f}|delta={delta:+.3f}"))
    blob["compile_cache"] = compile_cache_summary()
    return rows, blob
