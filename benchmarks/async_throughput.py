"""Async buffered engine: flush throughput + admission comm savings.

Three drivers on the reduced CNN corpus, compared at EQUAL flush count
(one async flush aggregates a buffer of K screened arrivals; one
synchronous round aggregates a full cohort — both ship exactly one
global-model assignment, so flushes/sec vs rounds/sec is the honest
throughput comparison):

  * ``fedavg_sync``      — sequential ``Server``, plain FedAvg: every
                           selected client uploads its model each round
                           (the round-synchronous baseline the paper's
                           comm numbers are quoted against);
  * ``fedentropy_sync``  — sequential ``Server``, max-entropy judgment:
                           round-synchronous, but only positive clients
                           ship models;
  * ``async_straggler``  — ``AsyncBufferedServer`` under the straggler
                           arrival clock (25% of clients 8x slower),
                           staleness damping α=0.5: arrivals are screened
                           one tie-batch at a time, rejected updates
                           never ship weights, admitted ones aggregate
                           with ``(1+τ)^-α`` damping.

The JSON blob sums uplink bytes over each engine's history and records
``async_model_bytes_lt_fedavg`` — the acceptance gate that the
straggler-clock async run ships strictly fewer uploaded-model bytes than
round-synchronous FedAvg at equal flush count.

Smoke mode (CI): best-of-5 blocks of 5 flushes each on a tiny 8-client
composition, artifact written to ``BENCH_async.json``:

  PYTHONPATH=src python -m benchmarks.async_throughput --smoke \
      --out BENCH_async.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.fl.runtime import (
    AsyncConfig, disable_process_cache, enable_process_cache,
    process_cache,
)

from .common import make_setup

# deliberately matches the recorded straggler golden + the engine tests
STRAGGLER = dict(clock="straggler", latency_scale=1.0, straggler_frac=0.25,
                 straggler_factor=8.0, staleness_alpha=0.5, seed=0)

# name -> (composition, build kwargs)
DRIVERS = {
    "fedavg_sync": ("fedavg", dict(engine=None, runtime=None)),
    "fedentropy_sync": ("fedentropy", dict(engine=None, runtime=None)),
    "async_straggler": ("fedentropy",
                        dict(engine="async",
                             runtime=AsyncConfig(**STRAGGLER))),
}

COMM_KEYS = ("soft_label_bytes", "model_bytes", "total_bytes",
             "fedavg_equivalent_bytes")


def _build(name: str, setup, local: LocalSpec, num_clients: int,
           participation: float, apply_fn):
    data, params, _ = setup
    comp, kwargs = DRIVERS[name]
    return fl.build(comp, apply_fn, params, data,
                    fl.ServerConfig(num_clients=num_clients,
                                    participation=participation, seed=0),
                    local, **kwargs)


def time_drivers(setup, local: LocalSpec, num_clients: int,
                 participation: float, apply_fn, flushes: int,
                 repeats: int = 5) -> list[dict]:
    """Best-of-``repeats`` timed blocks of ``flushes`` flushes per driver,
    interleaved round-robin so host-load drift hits every driver equally.
    Comm totals come from the FULL history (warmup + all blocks), so the
    savings ratios are averaged over many flushes, not one block."""
    def sync(server):
        jax.block_until_ready(server.global_params)

    servers = {}
    for name in DRIVERS:
        s = _build(name, setup, local, num_clients, participation, apply_fn)
        s.round()                             # warmup: compile + dispatch
        sync(s)
        servers[name] = s
    best = {name: float("inf") for name in DRIVERS}
    for _ in range(repeats):
        for name, server in servers.items():
            t0 = time.perf_counter()
            for _ in range(flushes):
                server.round()
            sync(server)
            best[name] = min(best[name], time.perf_counter() - t0)
    results = []
    for name, server in servers.items():
        dt = best[name]
        hist = server.history
        comm = {k: sum(h["comm"][k] for h in hist) for k in COMM_KEYS}
        rec = {"driver": name, "flushes": flushes, "wall_s": dt,
               "flushes_per_s": flushes / dt, "s_per_flush": dt / flushes,
               "repeats": repeats, "history_flushes": len(hist),
               "admitted": sum(len(h["positive"]) for h in hist),
               "rejected": sum(len(h["negative"]) for h in hist),
               "comm": comm,
               "model_bytes_per_flush": comm["model_bytes"] / len(hist)}
        if "staleness" in hist[-1]:
            stale = [t for h in hist for t in h["staleness"]]
            rec["staleness_max"] = max(stale)
            rec["staleness_mean"] = sum(stale) / len(stale)
            rec["buffer_occupancy_max"] = max(
                h["buffer_occupancy"] for h in hist)
        results.append(rec)
    return results


def run(fast: bool = False, smoke: bool = False):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    from repro.models import cnn

    if smoke:
        num_clients, participation, flushes = 8, 0.5, 5
        local = LocalSpec(epochs=1, batch_size=20)
    elif fast:
        num_clients, participation, flushes = 16, 0.25, 5
        local = LocalSpec(epochs=1, batch_size=24)
    else:
        num_clients, participation, flushes = 32, 0.156, 20
        local = LocalSpec(epochs=2, batch_size=24)

    setup = make_setup("case1", 0)
    if smoke or fast:   # trim the corpus to the reduced client count
        data, params, test = setup
        data = {k: v[:num_clients] for k, v in data.items()}
        setup = (data, params, test)

    enable_process_cache(maxsize=16)
    try:
        results = time_drivers(setup, local, num_clients, participation,
                               cnn.apply, flushes)
        cache_stats = process_cache().stats()
    finally:
        disable_process_cache()

    by_name = {r["driver"]: r for r in results}
    fedavg_models = by_name["fedavg_sync"]["comm"]["model_bytes"]
    async_models = by_name["async_straggler"]["comm"]["model_bytes"]
    rows = []
    for r in results:
        r["model_bytes_vs_fedavg"] = (r["comm"]["model_bytes"] /
                                      max(fedavg_models, 1))
        rows.append((f"async_{r['driver']}",
                     f"{r['s_per_flush'] * 1e6:.0f}",
                     f"{r['flushes_per_s']:.3f}fps/"
                     f"{r['model_bytes_vs_fedavg']:.3f}xB"))
    blob = {"results": results, "compile_cache": cache_stats,
            "num_clients": num_clients, "participation": participation,
            "flushes": flushes,
            "fedavg_model_bytes": fedavg_models,
            "async_model_bytes": async_models,
            # acceptance gate: straggler-clock async ships strictly fewer
            # model bytes than round-synchronous fedavg at equal flushes
            "async_model_bytes_lt_fedavg": async_models < fedavg_models,
            "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny composition, 5-flush blocks")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_async.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print("async model bytes < fedavg:",
          blob["async_model_bytes_lt_fedavg"],
          f"({blob['async_model_bytes']} vs {blob['fedavg_model_bytes']})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
