"""Engine throughput: sequential ``Server`` vs the runtime engines.

Two compositions — fedentropy (pools + maxent + weighted FedAvg) and
fedcat+maxent (entropy-grouped device chains + maxent + concatenation
merge, where the *group* is the dispatch unit) on the reduced CNN
corpus — three drivers each:

  * ``sequential``    — ``repro.fl.Server`` (the baseline round loop);
  * ``pipelined``     — ``PipelinedServer``, speculation off (sharding
                        "auto": identical program on one device, shard_map
                        client fan-out on many);
  * ``pipelined+spec``— speculation on: the float64 judgment oracle
                        overlaps the next round's in-flight client compute,
                        device verdict via the traced judge.

The process-level compile cache is enabled for the sweep, so the three
servers (same apply_fn/spec/shapes) share one compiled ClientUpdate —
the recompile-per-server cost the cache exists to kill is reported as
cache stats in the JSON blob.

Smoke mode (CI): best-of-5 blocks of 5 rounds each on a tiny 8-client
composition (~30 s total), artifact written to ``BENCH_engine.json`` so
the perf trajectory accumulates per commit.

  PYTHONPATH=src python -m benchmarks.engine_throughput --smoke \
      --out BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.fl.runtime import (
    RuntimeConfig, disable_process_cache, enable_process_cache,
    process_cache,
)

from .common import make_setup

ENGINES = {
    "sequential": dict(engine=None, runtime=None),
    "pipelined": dict(engine="pipelined", runtime=RuntimeConfig()),
    "pipelined+spec": dict(engine="pipelined",
                           runtime=RuntimeConfig(speculate=True)),
}


def _build(name: str, setup, local: LocalSpec, num_clients: int,
           participation: float, apply_fn, composition: str = "fedentropy"):
    data, params, _ = setup
    return fl.build(composition, apply_fn, params, data,
                    fl.ServerConfig(num_clients=num_clients,
                                    participation=participation, seed=0),
                    local, **ENGINES[name])


def time_engines(setup, local: LocalSpec, num_clients: int,
                 participation: float, apply_fn, rounds: int,
                 repeats: int = 5,
                 composition: str = "fedentropy") -> list[dict]:
    """Best-of-``repeats`` timed blocks of ``rounds`` rounds per engine,
    INTERLEAVED round-robin across engines so host-load drift hits every
    engine equally (spec-off pipelined runs the identical compiled program
    the sequential server does — any difference is measurement noise)."""
    def sync(server):
        """Drain ALL in-flight work, including a speculatively dispatched
        next round — otherwise a pending dispatch leaks its compute into
        the next engine's timed block."""
        jax.block_until_ready(server.global_params)
        pending = getattr(server, "_pending", None)
        if pending is not None:
            jax.block_until_ready(pending[1])

    servers = {}
    for name in ENGINES:
        s = _build(name, setup, local, num_clients, participation, apply_fn,
                   composition)
        s.round()                             # warmup: compile + dispatch
        sync(s)
        servers[name] = s
    best = {name: float("inf") for name in ENGINES}
    for _ in range(repeats):
        for name, server in servers.items():
            t0 = time.perf_counter()
            for _ in range(rounds):
                server.round()
            sync(server)
            best[name] = min(best[name], time.perf_counter() - t0)
    results = []
    for name, server in servers.items():
        dt = best[name]
        rec = {"engine": name, "rounds": rounds, "wall_s": dt,
               "rounds_per_s": rounds / dt, "s_per_round": dt / rounds,
               "repeats": repeats}
        hits = [h.get("spec_hit") for h in server.history
                if "spec_hit" in h]
        if hits:
            rec["spec_hit_rate"] = sum(hits) / len(hits)
            rec["redispatched"] = sum(
                1 for h in server.history if h.get("redispatched"))
        results.append(rec)
    return results


def run(fast: bool = False, smoke: bool = False):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    from repro.models import cnn

    if smoke:
        num_clients, participation, rounds = 8, 0.5, 5
        local = LocalSpec(epochs=1, batch_size=20)
    elif fast:
        num_clients, participation, rounds = 16, 0.25, 5
        local = LocalSpec(epochs=1, batch_size=24)
    else:
        num_clients, participation, rounds = 32, 0.156, 20
        local = LocalSpec(epochs=2, batch_size=24)

    setup = make_setup("case1", 0)
    if smoke or fast:   # trim the corpus to the reduced client count
        data, params, test = setup
        data = {k: v[:num_clients] for k, v in data.items()}
        setup = (data, params, test)

    enable_process_cache(maxsize=16)
    try:
        sweeps = {"fedentropy": time_engines(
            setup, local, num_clients, participation, cnn.apply, rounds)}
        sweeps["fedcat+maxent"] = time_engines(
            setup, local, num_clients, participation, cnn.apply, rounds,
            composition="fedcat+maxent")
        cache_stats = process_cache().stats()
    finally:
        disable_process_cache()

    rows, results = [], []
    for comp, res in sweeps.items():
        base = next(r for r in res if r["engine"] == "sequential")
        prefix = "engine" if comp == "fedentropy" else "engine_fedcat"
        for r in res:
            r["composition"] = comp
            r["speedup_vs_sequential"] = (r["rounds_per_s"] /
                                          base["rounds_per_s"])
            rows.append((f"{prefix}_{r['engine']}",
                         f"{r['s_per_round'] * 1e6:.0f}",
                         f"{r['rounds_per_s']:.3f}rps"))
            results.append(r)
    blob = {"results": results, "compile_cache": cache_stats,
            "num_clients": num_clients, "participation": participation,
            "rounds": rounds, "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny composition, 5-round blocks")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_engine.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print("compile cache:", blob["compile_cache"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
