"""Paper Fig. 3b ablation: FedEntropy vs FedEntropy-without-pools vs FedAvg.

Validated claim: both cloud-side components (maximum-entropy judgment and
the positive/negative pools) contribute; removing the pools degrades
FedEntropy toward (but usually still above) FedAvg.
"""
from __future__ import annotations

import time

from .common import SEEDS, compile_cache_summary, mean_std, run_method

CASE = "case1"


def run(fast: bool = False):
    seeds = SEEDS[:1] if fast else SEEDS
    rounds = 15 if fast else 60
    variants = {
        "fedentropy": dict(method="fedentropy"),
        "no_pools": dict(method="fedentropy", selector="uniform"),
        "fedavg": dict(method="fedavg"),
    }
    rows, blob = [], {}
    t0 = time.time()
    for name, kw in variants.items():
        accs = [run_method(CASE, seed, rounds=rounds, eval_every=0,
                           **kw)["final_accuracy"] for seed in seeds]
        blob[name] = mean_std(accs)
    dt = (time.time() - t0) * 1e6 / (len(seeds) * 3 * rounds)
    rows.append(("fig3b_ablation", f"{dt:.0f}",
                 "|".join(f"{k}={v[0]:.3f}" for k, v in blob.items())))
    blob["compile_cache"] = compile_cache_summary()
    return rows, blob
