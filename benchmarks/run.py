"""Benchmark runner — one function per paper table/figure + roofline.

Emits ``name,us_per_call,derived`` CSV rows per the harness contract, where
``derived`` carries the table's headline quantity (accuracy delta, byte
savings, ...). Full JSON results land in results/bench_*.json.

  PYTHONPATH=src python -m benchmarks.run               # all tables
  PYTHONPATH=src python -m benchmarks.run table1        # one table
Options: --fast (1 seed, fewer rounds) for CI-speed runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import ablation_fig3, accuracy_table1, async_throughput, \
    comm_table2, dataplane_bench, engine_throughput, microbench, roofline, \
    roundscan, stream_bench, synergy_table3

TABLES = {
    "table1": accuracy_table1.run,
    "table2": comm_table2.run,
    "table3": synergy_table3.run,
    "fig3": ablation_fig3.run,
    "micro": microbench.run,
    "roofline": roofline.run,
    "engine": engine_throughput.run,
    "dataplane": dataplane_bench.run,
    "async": async_throughput.run,
    "stream": stream_bench.run,
    "roundscan": roundscan.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("tables", nargs="*", default=[],
                    help=f"subset of {sorted(TABLES)} (default: all)")
    ap.add_argument("--fast", action="store_true",
                    help="1 seed / reduced rounds")
    ap.add_argument("--out-dir", default="results")
    args = ap.parse_args()

    names = args.tables or list(TABLES)
    os.makedirs(args.out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    ok = True
    for name in names:
        try:
            rows, blob = TABLES[name](fast=args.fast)
            for r in rows:
                print(",".join(str(x) for x in r), flush=True)
            with open(os.path.join(args.out_dir, f"bench_{name}.json"),
                      "w") as f:
                json.dump(blob, f, indent=1, default=str)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)
            ok = False
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
