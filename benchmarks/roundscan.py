"""One-program rounds: scan-engine throughput vs per-round dispatch.

Three drivers on an N=100-client corpus with deliberately tiny per-round
compute (10 samples/client, a 2-layer MLP instead of the paper CNN), so
the timed quantity is the engines' *per-round overhead* — host
round-trips, selector draws, oracle sync — not the client math:

  * ``sequential`` — the plain ``Server``: one host surfacing per round;
  * ``pipelined``  — ``PipelinedServer`` with verdict speculation ON:
                     still one dispatch per round, but judgment overlaps
                     the next round's client compute;
  * ``scan``       — ``ScanServer`` folding R rounds into ONE jitted
                     ``lax.scan``: the host is touched once per R rounds
                     (selector pre-draw in, oracle verdict replay out).

All three run the same fedentropy composition with the Fig. 3b uniform
selector, so the scan folds and every driver draws the identical cohort
stream — the blob asserts the scan's history (selection/verdict ints)
equals the sequential engine's. The headline is
``speedup_scan_vs_pipelined`` (acceptance gate: >= 2x rounds/sec at
N=100 on CPU).

A second section times the fused (M, P) aggregation
(``core.aggregation.fused_aggregate``, one flat segment-reduce) against
the per-leaf ``masked_mean_tree`` on a CNN pytree (few large leaves), an
LM-like pytree (many small leaves), and the same LM pytree with bf16
leaves — where the gate is the accumulate-dtype contract: the fused
paths must cast to f32 *before* reducing (``accum_f32_ok``: within 2x
the bf16 quantization floor of the exact float64 mean), exactly like
``masked_mean_tree``. On CPU the flatten itself (XLA's many-operand
concatenate) dominates, so the reported ratio prices the copy a
single-launch layout costs there; the launch-count saving the layout
buys is an accelerator property, the numerics contract (tolerance-equal
to the per-leaf mean) is what the suite gates on.

A third section reruns the engine race on the reduced LM fine-tune
workload (qwen3 reduced arch, full-window ``lmstep`` clients, the
``pools-traced`` selector folded into the scan, ``params_mode="remat"``)
— real per-round compute, so the gate is scan >= pipelined rounds/sec,
plus the memory claims: remat's stacked ys carry no params leaf and stay
below one copy of the model (stack mode pins R copies).

Smoke mode (CI): same N=100 corpus, fewer timed rounds, artifact written
to ``BENCH_roundscan.json``:

  PYTHONPATH=src python -m benchmarks.roundscan --smoke \
      --out BENCH_roundscan.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from repro.configs import ARCHS
from repro.core.aggregation import (
    fused_aggregate, masked_mean_tree, tree_bytes,
)
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.fl.runtime import RuntimeConfig, ScanConfig
from repro.launch.train import lm_window_apply, stack_lm_clients
from repro.models import cnn
from repro.models.api import build_model

NUM_CLIENTS = 100
PARTICIPATION = 0.1     # paper's C=0.1 at its N=100 scale
HW = 16
R = 16                  # rounds folded per scan program
LM_R = 8                # fold depth for the LM-arch section


def mlp_init(key, hw: int, num_classes: int) -> dict:
    """Tiny 2-layer MLP honoring the ``apply_fn -> (logits, feats)``
    contract; a LeNet round is ~25ms of conv on CPU, which would bury
    the per-round overhead this benchmark isolates."""
    k1, k2 = jax.random.split(key)
    din, hid = hw * hw * 3, 32
    return {
        "fc1": {"w": jax.random.normal(k1, (din, hid)) *
                jnp.sqrt(2.0 / din), "b": jnp.zeros((hid,))},
        "fc2": {"w": jax.random.normal(k2, (hid, 4)) *
                jnp.sqrt(2.0 / hid), "b": jnp.zeros((4,))},
    }


def mlp_apply(params: dict, x: jax.Array):
    h = x.reshape(x.shape[0], -1)
    feats = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    logits = feats @ params["fc2"]["w"] + params["fc2"]["b"]
    return logits, feats


def make_setup(seed: int = 0):
    """N=100 clients x 10 samples: round overhead dominates compute."""
    (xtr, ytr), _ = make_image_dataset(
        num_classes=4, train_per_class=250, test_per_class=5, hw=HW,
        noise=0.8, seed=seed)
    parts = partition("case1", ytr, NUM_CLIENTS, 4, seed=seed)
    data = stack_clients(xtr, ytr, parts, batch_multiple=10)
    params = mlp_init(jax.random.PRNGKey(seed), HW, 4)
    return data, params


# name -> build kwargs (same composition + selector stream everywhere)
DRIVERS = {
    "sequential": dict(engine=None, runtime=None),
    "pipelined": dict(engine="pipelined",
                      runtime=RuntimeConfig(speculate=True)),
    "scan": dict(engine="scan", runtime=ScanConfig(rounds_per_scan=R)),
}


def time_engines(data, params, rounds: int, repeats: int) -> list[dict]:
    """Best-of-``repeats`` timed blocks of ``rounds`` rounds per driver
    (``rounds`` is a multiple of R so every scan block is full-depth),
    interleaved round-robin so host-load drift hits every driver equally.
    """
    def sync(server):
        jax.block_until_ready(server.global_params)

    servers = {}
    for name, kwargs in DRIVERS.items():
        s = fl.build("fedentropy", mlp_apply, params, data,
                     fl.ServerConfig(num_clients=NUM_CLIENTS,
                                     participation=PARTICIPATION, seed=0),
                     LocalSpec(epochs=1, batch_size=10),
                     selector="uniform", **kwargs)
        for _ in range(R):            # warmup: compile + one full block
            s.round()
        sync(s)
        servers[name] = s
    assert servers["scan"].scan_rounds() == R
    best = {name: float("inf") for name in DRIVERS}
    for _ in range(repeats):
        for name, server in servers.items():
            t0 = time.perf_counter()
            for _ in range(rounds):
                server.round()
            sync(server)
            best[name] = min(best[name], time.perf_counter() - t0)
    results = []
    for name, server in servers.items():
        dt = best[name]
        results.append({
            "driver": name, "rounds": rounds, "wall_s": dt,
            "rounds_per_s": rounds / dt, "s_per_round": dt / rounds,
            "repeats": repeats, "history_rounds": len(server.history),
            "spec_hits": sum(1 for h in server.history
                             if h.get("spec_hit"))})
    return results, servers


def histories_match(a, b) -> bool:
    """Selection/verdict int equality over the common prefix."""
    n = min(len(a), len(b))
    return all(a[i]["selected"] == b[i]["selected"]
               and a[i]["positive"] == b[i]["positive"]
               and a[i]["negative"] == b[i]["negative"]
               for i in range(n)) and n > 0


def _lm_like(m: int, seed: int = 0):
    """Many small leaves + one embedding: the launch-count win case."""
    rng = np.random.default_rng(seed)
    tree = {"emb": jnp.asarray(rng.normal(size=(m, 256, 64)), jnp.float32)}
    for i in range(24):
        tree[f"blk{i}"] = {
            "attn": jnp.asarray(rng.normal(size=(m, 64, 64)), jnp.float32),
            "mlp": jnp.asarray(rng.normal(size=(m, 64, 128)), jnp.float32),
            "ln": jnp.asarray(rng.normal(size=(m, 64)), jnp.float32),
        }
    return tree


def _accum_f32_check(tree, sizes, mask) -> tuple[float, float, bool]:
    """Accumulate-dtype gate for low-precision leaves.

    The exact weighted mean is computed in numpy float64; the best any
    f32-accumulating path can do is that mean quantized to the leaf
    dtype. The fused paths must land within 2x that quantization floor —
    accumulating IN bf16 (the bug this gates against) drifts well past
    it, while f32 accumulation + one cast-back sits on it.
    """
    w = np.asarray(sizes, np.float64) * np.asarray(mask, np.float64)
    tot = max(w.sum(), 1e-12)

    def exact(x):
        return np.einsum("m,m...->...", w,
                         np.asarray(x, np.float64)) / tot

    refs = [exact(x) for x in jax.tree.leaves(tree)]
    floor = max(
        float(np.max(np.abs(np.asarray(
            jnp.asarray(r).astype(x.dtype), np.float64) - r)))
        for r, x in zip(refs, jax.tree.leaves(tree)))
    errs = []
    for backend in ("xla", "pallas"):
        got = fused_aggregate(tree, sizes, mask, backend=backend)
        errs.append(max(
            float(np.max(np.abs(np.asarray(g, np.float64) - r)))
            for g, r in zip(jax.tree.leaves(got), refs)))
    err = max(errs)
    return err, floor, bool(err <= 2.0 * floor + 1e-7)


def time_aggregation(repeats: int = 200) -> dict:
    """Jitted per-leaf tree_map mean vs the one-launch fused reduce."""
    m = 10
    cnn_params = cnn.init(jax.random.PRNGKey(0), image_hw=HW,
                          num_classes=4)
    cnn_tree = jax.tree.map(
        lambda x: jnp.stack([x + 0.01 * i for i in range(m)]), cnn_params)
    lm_tree = _lm_like(m)
    # bf16 leaves: PR 8 made masked_mean_tree accumulate low-precision
    # leaves in f32; the fused paths cast to f32 BEFORE the flatten, so
    # they must meet the same accumulate-dtype contract (gated below)
    lm_bf16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), lm_tree)
    trees = {"cnn": cnn_tree, "lm": lm_tree, "lm_bf16": lm_bf16}
    sizes = jnp.asarray(np.full(m, 10.0), jnp.float32)
    mask = jnp.asarray(([1.0, 0.0] * m)[:m], jnp.float32)

    tree_fn = jax.jit(masked_mean_tree)
    fused_fn = jax.jit(lambda t, s, k: fused_aggregate(t, s, k,
                                                       backend="xla"))
    out = {}
    for name, tree in trees.items():
        leaves = jax.tree.leaves(tree)
        rec = {"leaves": len(leaves),
               "params": int(sum(x[0].size for x in leaves)),
               "dtype": str(leaves[0].dtype)}
        for label, fn in (("tree", tree_fn), ("fused_xla", fused_fn)):
            jax.block_until_ready(fn(tree, sizes, mask))   # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                r = fn(tree, sizes, mask)
            jax.block_until_ready(r)
            rec[f"{label}_us"] = (time.perf_counter() - t0) / repeats * 1e6
        # numerics: the Pallas kernel path agrees (interpret mode on CPU
        # is far too slow to time honestly — checked, not raced)
        got = fused_aggregate(tree, sizes, mask, backend="pallas")
        want = masked_mean_tree(tree, sizes, mask)
        rec["pallas_max_err"] = float(max(
            jnp.max(jnp.abs(g.astype(jnp.float32) - w.astype(jnp.float32)))
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want))))
        err, floor, ok = _accum_f32_check(tree, sizes, mask)
        rec["accum_err"] = err
        rec["accum_floor"] = floor
        rec["accum_f32_ok"] = ok
        out[name] = rec
    return out


# ---- LM-arch engine section ----------------------------------------------

def make_lm_setup(seed: int = 0):
    """Reduced LM fine-tune workload: the fedentropy composition with the
    scan-foldable pools and the full-window lmstep client rule."""
    cfg = ARCHS["qwen3-0.6b"].reduced().replace(
        remat="none", param_dtype="float32", dtype="float32")
    model = build_model(cfg)
    logical, samples, seq = 8, 4, 16
    corpus, dom = make_token_dataset(
        vocab_size=min(cfg.vocab_size, 512), num_domains=logical,
        docs_per_domain=16, seq_len=seq, seed=seed)
    idx = [np.where(dom == c % logical)[0] for c in range(logical)]
    data = stack_lm_clients(corpus, idx, samples, seq, seed)
    params = model.init(jax.random.PRNGKey(seed))
    return lm_window_apply(model, cfg), data, params


def time_lm_engines(rounds: int, repeats: int) -> tuple[list[dict], dict]:
    """scan (pools folded, remat) vs pipelined vs sequential on the LM
    workload; per-round compute is real here, so the scan's win is the
    removed host surfacing, not free — the gate is >= pipelined."""
    apply_fn, data, params = make_lm_setup(0)
    config = fl.ServerConfig(num_clients=8, participation=0.5, seed=0)
    local = LocalSpec(lr=0.05, epochs=1, batch_size=4)
    drivers = {
        "sequential": dict(engine=None, runtime=None),
        "pipelined": dict(engine="pipelined",
                          runtime=RuntimeConfig(speculate=True)),
        "scan": dict(engine="scan",
                     runtime=ScanConfig(rounds_per_scan=LM_R,
                                        params_mode="remat")),
    }
    servers, best = {}, {}
    for name, kwargs in drivers.items():
        s = fl.build("fedentropy", apply_fn, params, data, config, local,
                     selector="pools-traced", strategy="lmstep", **kwargs)
        for _ in range(2 * LM_R):      # warmup: compile + two full blocks
            s.round()
        jax.block_until_ready(s.global_params)
        servers[name] = s
        best[name] = float("inf")
    scan = servers["scan"]
    assert scan.scan_rounds() == LM_R, scan.fallback_reasons
    for _ in range(repeats):
        for name, server in servers.items():
            t0 = time.perf_counter()
            for _ in range(rounds):
                server.round()
            jax.block_until_ready(server.global_params)
            best[name] = min(best[name], time.perf_counter() - t0)
    results = [{"driver": name, "rounds": rounds, "wall_s": best[name],
                "rounds_per_s": rounds / best[name],
                "s_per_round": best[name] / rounds, "repeats": repeats}
               for name in drivers]
    by = {r["driver"]: r for r in results}
    # memory: remat ys carry no params leaf; a stack-mode twin of the
    # same block (eval_shape only — nothing runs) shows what R copies of
    # the pytree would have pinned
    stack_twin = fl.build(
        "fedentropy", apply_fn, params, data, config, local,
        selector="pools-traced", strategy="lmstep", engine="scan",
        runtime=ScanConfig(rounds_per_scan=LM_R, params_mode="stack"))
    remat_shapes = scan.block_ys_shapes(LM_R)
    blob = {
        "arch": "qwen3-0.6b (reduced)", "rounds_per_scan": LM_R,
        "speedup_scan_vs_pipelined": (by["scan"]["rounds_per_s"] /
                                      by["pipelined"]["rounds_per_s"]),
        "scan_ge_pipelined": (by["scan"]["rounds_per_s"] >=
                              by["pipelined"]["rounds_per_s"]),
        "scan_matches_sequential": histories_match(
            scan.history, servers["sequential"].history),
        "remat_ys_params_free": "params" not in remat_shapes,
        "remat_ys_nbytes": scan.stacked_ys_nbytes(LM_R),
        "stack_ys_nbytes": stack_twin.stacked_ys_nbytes(LM_R),
        "params_nbytes": tree_bytes(params),
        # the LM-scale claim: a remat block's stacked ys stay below even
        # ONE copy of the model, vs R copies in stack mode
        "remat_ys_lt_params": (scan.stacked_ys_nbytes(LM_R) <
                               tree_bytes(params)),
        "mismatch_rounds": scan.stats()["mismatch_rounds"],
    }
    return results, blob


def run(fast: bool = False, smoke: bool = False):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    if smoke or fast:
        rounds, repeats, agg_repeats = 2 * R, 2, 50
        lm_rounds, lm_repeats = 2 * LM_R, 3
    else:
        rounds, repeats, agg_repeats = 4 * R, 5, 200
        lm_rounds, lm_repeats = 4 * LM_R, 3

    data, params = make_setup(0)
    results, servers = time_engines(data, params, rounds, repeats)

    by_name = {r["driver"]: r for r in results}
    speedup = (by_name["scan"]["rounds_per_s"] /
               by_name["pipelined"]["rounds_per_s"])
    match = histories_match(servers["scan"].history,
                            servers["sequential"].history)
    agg = time_aggregation(agg_repeats)
    lm_results, lm = time_lm_engines(lm_rounds, lm_repeats)

    rows = []
    for r in results:
        rows.append((f"roundscan_{r['driver']}",
                     f"{r['s_per_round'] * 1e6:.0f}",
                     f"{r['rounds_per_s']:.2f}rps"))
    for r in lm_results:
        rows.append((f"roundscan_lm_{r['driver']}",
                     f"{r['s_per_round'] * 1e6:.0f}",
                     f"{r['rounds_per_s']:.2f}rps"))
    for name, rec in agg.items():
        rows.append((f"roundscan_agg_{name}", f"{rec['fused_xla_us']:.0f}",
                     f"{rec['tree_us'] / rec['fused_xla_us']:.2f}x1launch"))
    blob = {"results": results, "rounds_per_scan": R,
            "num_clients": NUM_CLIENTS, "participation": PARTICIPATION,
            "speedup_scan_vs_pipelined": speedup,
            # acceptance gate: one program per R rounds beats per-round
            # dispatch by >= 2x when round overhead dominates
            "speedup_ge_2x": speedup >= 2.0,
            "scan_matches_sequential": match,
            "aggregation": agg,
            "lm": {"results": lm_results, **lm},
            "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer timed rounds")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_roundscan.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print("scan matches sequential:", blob["scan_matches_sequential"])
    print(f"scan vs pipelined: {blob['speedup_scan_vs_pipelined']:.2f}x "
          f"(>=2x: {blob['speedup_ge_2x']})")
    lm = blob["lm"]
    print(f"lm scan vs pipelined: "
          f"{lm['speedup_scan_vs_pipelined']:.2f}x "
          f"(>=1x: {lm['scan_ge_pipelined']}, "
          f"matches sequential: {lm['scan_matches_sequential']})")
    print(f"lm remat ys: {lm['remat_ys_nbytes']}B vs "
          f"{lm['stack_ys_nbytes']}B stacked, params "
          f"{lm['params_nbytes']}B "
          f"(params-free: {lm['remat_ys_params_free']})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
