"""Microbenchmarks: judgment throughput + kernel-vs-reference timings on CPU.

Wall-times here are CPU curiosities (TPU is the target); the point is the
scaling shape (judgment cost vs M and C) and that the jitted while_loop
judgment is usable inside a train step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.judgment import judge, judge_np


def _time(fn, *args, iters=5):
    fn(*args)                       # compile / warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(fast: bool = False):
    rows, blob = [], {}
    rng = np.random.default_rng(0)
    jj = jax.jit(lambda p, s: judge(p, s).mask)

    for (m, c) in [(10, 10), (16, 1024), (32, 65536)]:
        p = jnp.asarray(rng.dirichlet(np.full(c, 0.3), size=m), jnp.float32)
        s = jnp.asarray(rng.integers(10, 500, m), jnp.float32)
        us_jax = _time(jj, p, s)
        t0 = time.time()
        judge_np(np.asarray(p), np.asarray(s))
        us_np = (time.time() - t0) * 1e6
        blob[f"judge_M{m}_C{c}"] = {"jax_us": us_jax, "numpy_us": us_np}
        rows.append((f"judge_M{m}_C{c}", f"{us_jax:.0f}",
                     f"numpy_us={us_np:.0f}|speedup={us_np / us_jax:.1f}x"))

    # kernel sanity timing (interpret mode — correctness harness, not perf)
    if not fast:
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.ref import mha_reference
        q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        us_ref = _time(jax.jit(
            lambda a, b, c_: mha_reference(a, b, c_)), q, k, v, iters=3)
        rows.append(("mha_reference_128", f"{us_ref:.0f}",
                     "xla_reference_path"))
        err = float(jnp.abs(
            flash_attention(q, k, v, block_q=32, block_k=32) -
            mha_reference(q, k, v)).max())
        rows.append(("flash_vs_ref_maxerr", "0", f"{err:.2e}"))
        blob["flash_err"] = err
    return rows, blob
