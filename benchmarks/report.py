"""Render EXPERIMENTS.md from results/*.json artifacts.

  PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md

Sections: §Repro (paper tables at reduced scale), §Dry-run, §Roofline
(single-pod baseline, all combos), §Perf (the three hillclimbed pairs,
hypothesis->change->measure log, baseline vs beyond-paper optimized).
"""
from __future__ import annotations

import json
import os

R = "results"


def load(name):
    p = os.path.join(R, name)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def sec_repro(out):
    out.append("## §Repro — paper tables at reduced scale\n")
    out.append(
        "Offline container ⇒ synthetic class-conditional image data "
        "(orthonormal class templates + Gaussian noise, DESIGN.md §2.3); "
        "N=32 clients, |S_t|=5, T=60 rounds (accuracy = mean of the last "
        "10 rounds, per paper Sec. 4.2), 6 classes, 3 seeds (paper: N=100, "
        "|S_t|=10, T=1000, CIFAR/CINIC). What reduced scale validates — "
        "and what it honestly does not:\n\n"
        "* **case 1 (strongest non-IID, the paper's headline)**: FedEntropy "
        "decisively beats every baseline (+0.27 over the best), and the "
        "Fig. 3b ablation reproduces the paper's ordering exactly — "
        "judgment+pools > FedAvg > judgment-without-pools, i.e. BOTH cloud "
        "components contribute, as the paper claims.\n"
        "* **Table 3 synergy**: positive for all four optimizers "
        "(FedAvg/FedProx strongly, SCAFFOLD/Moon marginally) — the paper's "
        "orthogonality claim holds.\n"
        "* **cases 2/3 (milder heterogeneity)**: FedEntropy trails FedAvg "
        "at T=60 (vs the paper's T=1000). A *scale-dependent deviation*: "
        "with milder skew the judgment filters less decisively while the "
        "ε-greedy pools still pay their exploration cost up front "
        "(~N/|S_t| rounds to cycle the population once); the paper itself "
        "shows its thinnest margins in case 3.\n"
        "* **communication (Table 2)**: unconditional — every judged round "
        "uploads fewer model bytes; 36-40% uplink-byte savings at equal "
        "round counts, matching (indeed exceeding) the paper's claim.\n")
    t1 = load("bench_table1.json")
    if t1:
        out.append("### Table 1 — test accuracy (mean over seeds)\n")
        out.append("| case | fedavg | fedprox | scaffold | moon | "
                   "**fedentropy** |")
        out.append("|---|---|---|---|---|---|")
        for case, stats in t1["cases"].items():
            row = [case] + [
                f"{stats[m][0]:.3f}±{stats[m][1]:.3f}"
                for m in ("fedavg", "fedprox", "scaffold", "moon",
                          "fedentropy")]
            out.append("| " + " | ".join(row) + " |")
        out.append("")
    t2 = load("bench_table2.json")
    if t2:
        out.append("### Table 2 — communication to target accuracy\n")
        out.append("| case | target | rounds fedavg | rounds fedentropy | "
                   "uplink bytes fedavg | fedentropy | saving |")
        out.append("|---|---|---|---|---|---|---|")
        for case, s in t2.items():
            ra = s["rounds_to_target"]["fedavg"][0]
            rf = s["rounds_to_target"]["fedentropy"][0]
            ba = s["uplink_bytes"]["fedavg"][0]
            bf = s["uplink_bytes"]["fedentropy"][0]
            out.append(
                f"| {case} | {s['target']:.0%} | {ra:.1f} | {rf:.1f} | "
                f"{ba / 1e6:.1f}MB | {bf / 1e6:.1f}MB | "
                f"{1 - bf / max(ba, 1):.1%} |")
        out.append("")
    t3 = load("bench_table3.json")
    if t3:
        out.append("### Table 3 — synergy with other FL optimizers "
                   "(case 1)\n")
        out.append("| optimizer | plain | + fedentropy | delta |")
        out.append("|---|---|---|---|")
        for strat, s in t3.items():
            out.append(f"| {strat} | {s['plain'][0]:.3f} | "
                       f"{s['with_fedentropy'][0]:.3f} | "
                       f"{s['with_fedentropy'][0] - s['plain'][0]:+.3f} |")
        out.append("")
    f3 = load("bench_fig3.json")
    if f3:
        out.append("### Fig. 3b — component ablation (case 1)\n")
        out.append("| variant | accuracy |")
        out.append("|---|---|")
        for k, v in f3.items():
            out.append(f"| {k} | {v[0]:.3f}±{v[1]:.3f} |")
        out.append("")
    eps = load("bench_eps.json")
    if eps:
        out.append("### ε-sensitivity (beyond-paper ablation, case 1)\n")
        out.append("| ε | accuracy (3 seeds) |")
        out.append("|---|---|")
        for k, v in eps.items():
            out.append(f"| {k} | {v['mean']:.3f} |")
        out.append(
            "\nThe paper's ε=0.8 is confirmed as the sweet spot: pure "
            "exploitation (ε=1.0 — negatives never revisited) and heavy "
            "exploration (ε=0.5 — 50% of rounds aggregate previously-"
            "harmful clients) both roughly halve the accuracy.\n")


def _fits(r):
    m = r["memory_analysis"]
    per_dev = m.get("argument_size_in_bytes", 0) + \
        m.get("temp_size_in_bytes", 0)
    return per_dev / 2**30


def sec_dryrun(out):
    out.append("## §Dry-run — 10 archs × 4 shapes × {16×16, 2×16×16}\n")
    for tag, fname in (("single-pod (256 chips)", "dryrun_single_pod.json"),
                       ("multi-pod (512 chips)", "dryrun_multi_pod.json"),
                       ("multi-pod, optimized defaults",
                        "dryrun_multi_pod_optimized.json")):
        recs = load(fname)
        if not recs:
            continue
        ok = [r for r in recs if r["status"] == "ok"]
        skip = [r for r in recs if r["status"] == "skipped"]
        err = [r for r in recs if r["status"] == "error"]
        out.append(f"### {tag}: {len(ok)} lowered+compiled, "
                   f"{len(skip)} documented skip, {len(err)} errors\n")
        out.append("| arch | shape | compile s | args+temp GiB/dev | "
                   "fits 16 GiB | collectives |")
        out.append("|---|---|---|---|---|---|")
        for r in ok:
            gb = _fits(r)
            colls = ",".join(f"{k}:{v}" for k, v in
                             sorted(r["collective_counts"].items()))
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | "
                f"{gb:.2f} | {'yes' if gb <= 16 else 'NO'} | {colls} |")
        for r in skip:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | skip | "
                       f"{r['reason'][:70]} |")
        out.append("")
    out.append(
        "Skip note: whisper-large-v3 × long_500k is the single documented "
        "skip (bounded-context architecture, DESIGN.md §4). Combos over "
        "16 GiB/device are honest baseline findings — §Perf drives the "
        "three chosen ones down; the rest are listed with their dominant "
        "cause in §Roofline notes.\n")


def sec_roofline(out):
    recs = load("dryrun_single_pod.json")
    if not recs:
        return
    out.append("## §Roofline — single-pod baseline, per (arch × shape)\n")
    out.append(
        "Terms (seconds/step/device): compute = loop-aware HLO dot-FLOPs / "
        "197 TF/s; memory = bytes-accessed / 819 GB/s; collective = "
        "collective operand bytes / 50 GB/s. `useful` = 6·N_active·D / "
        "(HLO FLOPs × chips). Methodology: cost_analysis() counts while "
        "bodies once, so terms come from the loop-aware HLO walker "
        "(launch/hlo_analysis.py); memory follows HloCostAnalysis "
        "conventions (fusion operands+result; sliced access for "
        "dynamic-slice/DUS).\n")
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful | one-line diagnosis |")
    out.append("|---|---|---|---|---|---|---|---|")
    diag = {
        ("whisper-large-v3", "prefill_32k"):
            "20 heads ∤ 16 ⇒ attention replicated over model axis + S² "
            "scores (fixed in §Perf)",
        ("whisper-large-v3", "train_4k"):
            "same head-indivisibility replication",
        ("qwen3-moe-235b-a22b", "decode_32k"):
            "1-token MoE: expert weights streamed for 128 tokens/shard",
        ("kimi-k2-1t-a32b", "decode_32k"):
            "1-token MoE: 1T weights streamed; batch 128 too small to "
            "amortize",
        ("kimi-k2-1t-a32b", "long_500k"):
            "B=1 decode: whole pod idle except weight streaming",
        ("kimi-k2-1t-a32b", "train_4k"):
            "MoE a2a + FSDP gathers; hillclimbed in §Perf",
    }
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        d = diag.get((r["arch"], r["shape"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant'][:-2]} | {r['useful_flops_ratio'] * 100:.1f}% "
            f"| {d} |")
    out.append("")
    out.append(
        "Reading: every baseline combo is **memory-term dominated** — the "
        "XLA-reference attention materializes S² score tensors and the "
        "fp32 vocab head streams (B,S,V); decode shapes additionally "
        "stream all weights for one token (inherent at batch ≤ 128). "
        "`useful` < 50% flags replicated compute (indivisible heads), "
        "remat recompute, and MoE capacity padding.\n")
    opt = load("dryrun_single_pod_optimized.json")
    if opt:
        out.append("### Optimized sweep (blockwise attention + chunked "
                   "head + capacity 1.0) — beyond-paper defaults\n")
        out.append("| arch | shape | memory s (base→opt) | GiB/dev "
                   "(base→opt) | useful (base→opt) |")
        out.append("|---|---|---|---|---|")
        base = {(r["arch"], r["shape"]): r for r in recs
                if r["status"] == "ok"}
        for r in opt:
            if r["status"] != "ok":
                continue
            b = base.get((r["arch"], r["shape"]))
            if not b:
                continue
            out.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{b['roofline']['memory_s']:.2f}→"
                f"{r['roofline']['memory_s']:.2f} | "
                f"{_fits(b):.1f}→{_fits(r):.1f} | "
                f"{b['useful_flops_ratio'] * 100:.0f}%→"
                f"{r['useful_flops_ratio'] * 100:.0f}% |")
        out.append("")


PERF_LOG = """## §Perf — hillclimbing the three chosen pairs

Chosen per the assignment: **whisper-large-v3 × prefill_32k** (worst
roofline fraction, useful 3.5%), **kimi-k2-1t-a32b × train_4k** (largest
collective term, 23.0 s), **qwen3-0.6b × train_4k** (most representative
of the paper's technique — the full FedEntropy train step: in-step
soft-label collection, while-loop judgment, masked weighted aggregation).

The paper itself contains no kernel/sharding contribution (aggregation
heuristic; repro band 2/5), so the *paper-faithful baseline* is the
unoptimized framework executing FedEntropy semantics exactly; every row
below is a **beyond-paper** systems optimization that leaves FedEntropy
semantics bit-identical (verified: optimized and baseline train steps
produce the same masks/losses in tests).

### qwen3-0.6b × train_4k   (baseline: cmp 0.167 s | mem 3.396 s | col 1.398 s | useful 44.5% | 19.09 GiB/dev)

| it | hypothesis (napkin math) | change | result | verdict |
|---|---|---|---|---|
| 1 | S² scores (16·16·4096²·4B ≈ 4.3 GiB/dev·layer traffic) dominate memory term; blockwise attention removes them | `--attn blockwise` (flash-style lax.scan, online softmax, per-block remat) | mem 3.40→4.12 s (+21%), peak 19.1→19.1 GiB | **REFUTED** at S=4096: per-device scores are modest after head-sharding; checkpoint recompute *adds* traffic; peak unmoved ⇒ peak is not scores |
| 2 | fp32 logits+softmax chains ((B,S,V): 2.7 GiB/dev ×~4 live copies for CE + Eq.2 soft labels) drive the 19 GiB peak | `--chunked-head` (stream vocab projection + CE + soft-label accumulation in 512-token chunks, per-chunk remat) | peak 19.09→**12.47 GiB (fits)**, mem 3.40→3.41 s, masks/loss bit-identical | **CONFIRMED** for peak; traffic neutral (recompute ≈ savings) |
| 3 | with peak fixed, remaining mem term is FSDP weight streaming (irreducible at this size) + attention; further <5% expected | stop (two consecutive <5% candidates) | — | stopping rule hit |

Final: chunked head. The FedEntropy-specific cost (judgment while-loop +
(M,V) soft labels) measures <0.1% of any term — the paper's claim that
stage-1 soft labels are negligible holds at 152k-class LM scale.

### whisper-large-v3 × prefill_32k   (baseline: cmp 1.845 s | mem 70.78 s | col 0.72 s | useful 3.5% | 326.5 GiB/dev)

| it | hypothesis | change | result | verdict |
|---|---|---|---|---|
| 1 | S²=32k² scores (86 GiB/layer) are the 326 GiB peak | `--attn blockwise` | peak 326.5→**6.35 GiB**, mem 70.8→76.9 s (+8% recompute) | **CONFIRMED** for peak; traffic needs the second lever |
| 2 | 20 heads ∤ 16 ⇒ the whole attention replicates over the model axis: 16× redundant compute AND traffic; shard the *seq* dim over "model" instead | `--seq-rule` (sequence-parallel activations) | cmp 1.845→**0.155 s (11.9×)**, mem 76.9→**5.35 s (14.4×)**, col 0.72→0.059 s, useful 3.5→**41.1%**, peak 1.08 GiB | **CONFIRMED** — head-indivisibility was the real bottleneck |

Final: blockwise + sequence-parallelism. 13.2× memory-term and 11.9×
compute-term reduction; the arch now fits a single host's HBM with 15×
headroom. Lesson: divisibility-aware *fallback-to-replication* (the safe
default) must fall back to a *different parallel axis*, not to replication.

The same lever stack applied to whisper × **train_4k** (not one of the
three chosen pairs; measured for completeness): cmp 1.22→0.39 s (3.2×),
mem 63.5→16.8 s (3.8×), useful 15.7→49.3%, peak 312→67.7 GiB — still
over budget because the *cross*-attention's 20 heads keep partially
replicating (XLA SPMD logs "involuntary full rematerialization" on the
enc-KV reshard). Next lever (napkin'd, unimplemented): pad attention
heads 20→32 at the parameter level for clean 16-way head sharding
(+60% attention params, −16× cross-attn activation replication).

### kimi-k2-1t-a32b × train_4k   (baseline: cmp 6.89 s | mem 47.02 s | col 22.98 s | useful 56.0% | 71.6 GiB/dev)

| it | hypothesis | change | result | verdict |
|---|---|---|---|---|
| 1 | 164k-vocab head matters at 1M tokens | `--chunked-head` | all terms ±0.2% | **REFUTED** — head ≪ 61 layers of 384-expert MoE |
| 2 | capacity 1.25→1.0 cuts a2a payload 20% and padded expert FLOPs | `--capacity-factor 1.0` | col 23.1→21.2 s (−8%), mem −9%, useful 56→65% | **CONFIRMED** (smaller than napkin: FSDP gathers, not a2a, are the larger collective) |
| 3 | `remat=dots` saves matmul outputs ⇒ bwd re-gathers fewer FSDP shards | `--remat dots` | col −2%, but temp 54.6→**217.8 GiB** (4×) | **REFUTED/rejected** — saved dot outputs explode memory |
| 4 | activations replicated on "model" axis make fwd-recompute all-gathers 16× too big | `--seq-rule` | col 21.2→**15.5 s (−27%)**, mem →39.3 s, useful 61.4% | **CONFIRMED** |
| 5 | remaining 54.6 GiB temp = materialized attention scores (16·64·256·4096·4B ≈ 17 GiB/layer transient) | `--attn blockwise` on top | temp 54.6→47.3 GiB but mem term +17% (recompute) | **partially confirmed** — scores were ~7 GiB; traded away, kept OFF |

Final config: capacity 1.0 + sequence-parallel activations:
**collective 23.0→15.5 s (−33%)**, memory 47.0→39.3 s (−16%), useful
56→61.4%. Honest finding: kimi-k2 train at 4k×256 does **not** fit a
single 256-chip v5e pod (69.7 GiB/dev incl. 15.2 GiB FSDP-sharded
params+momentum); the multi-pod 512-chip mesh (§Dry-run) halves state and
is the deployment target. Next levers (unimplemented, napkin'd):
micro-batched a2a (stream capacity in 4 slices: −75% dispatch transient),
fp8 dispatch payloads (−50% a2a bytes).

### Bonus iteration: KV-cache time sharding for decode shapes

Hypothesis: the four archs whose kv_heads don't divide the 16-way "model"
axis (whisper kv=20, chatglm kv=2, qwen3-moe kv=4, kimi kv=8) replicate
their ENTIRE KV cache across the model axis during decode — the dominant
decode buffer. Sharding the cache *time* dimension over "model" instead
(`--kv-time-rule`; distributed-softmax reduction handled by XLA SPMD):

| arch | decode_32k | memory_s | GiB/dev | fits 16 GiB |
|---|---|---|---|---|
| whisper-large-v3 | base → kv_time | 7.81 → **0.29 (27×)** | 63.8 → **11.3** | NO → **yes** |
| qwen3-moe-235b | base → kv_time | 20.2 → **1.18 (17×)** | 67.9 → **15.2** | NO → **yes** |
| kimi-k2-1t | base → kv_time | 2.67 → 2.28 | 100.8 → **40.4** | NO → NO (params-bound; needs multi-pod) |
| chatglm3-6b | base → kv_time | 0.44 → **0.054 (8×)** | 8.4 → **1.8** | yes → yes |

**CONFIRMED** — three more production combos become single-pod-feasible.
This generalizes the whisper lesson: whenever a preferred sharding axis is
indivisible, route the parallelism to a *different* tensor dimension
(seq for activations, time for caches) instead of replicating.

### Bonus iteration: two-phase microbatching (kimi multi-pod) — REFUTED

Hypothesis: kimi train on the 512-chip mesh with all levers still peaks at
39.7 GiB/dev (temp 32.1), dominated by per-layer activations at global
batch 256; a two-phase microbatched round (phase 1 = forward-only
soft-label accumulation + one judgment — literally the paper's stage 1;
phase 2 = gradient accumulation with the judged mask — stage 2) at n=4
should cut activation temp ~4x toward ~15 GiB.

Measured: temp 32.1→**42.4 GiB (worse)**, collective bytes 570→**1814 GB
(3.2x)**. Refuted on both terms: (a) the f32 gradient accumulator is
resident across the scan (1.06T params x 4 B / 512 = **8.3 GiB** + scan
double-buffering); (b) phase 1 re-runs every FSDP weight gather, and each
phase-2 microbatch re-gathers the full 2 TB parameter set — collectives
scale with n_microbatches for an FSDP-sharded giant, the opposite of the
dense-model intuition. Lessons: microbatching giant-MoE FSDP training
needs bf16/reduce-scattered gradient accumulation and gather reuse across
microbatches before it pays; the feature (with an exactness test vs the
fused step) stays in the framework for activation-bound *dense* models.
The fused single-pass FedEntropy step remains the production default.

### FedEntropy-specific distributed cost (the paper's own technique)

Measured inside the qwen3 train step (single-pod, M=16 clients):
soft-label collection (M,V) = 9.7 MB gathered; judgment while-loop ≤ M-1
iterations of an O(M·V) sweep = <2 ms compute; masked aggregation reuses
the existing gradient all-reduce with per-client weights — the paper's
"communication savings" materialize as negative devices contributing zero
gradient (on WAN cross-silo FL, their model bytes are never sent; on a
pod, the all-reduce payload is unchanged but its *information* content is
the judged subset). Stage-1 soft-label traffic is 0.03% of one FSDP layer
gather — the paper's negligibility claim holds three orders of magnitude
beyond its CIFAR setting.
"""


def main():
    out = ["# EXPERIMENTS — FedEntropy framework\n"]
    out.append(
        "Artifacts: results/*.json (regenerate: `make sweeps` or the "
        "commands in each section). Hardware model: TPU v5e — 197 TF/s "
        "bf16, 819 GB/s HBM, 50 GB/s/link ICI; CPU container ⇒ all "
        "roofline terms are derived from compiled HLO, not wall clock.\n")
    sec_repro(out)
    sec_dryrun(out)
    sec_roofline(out)
    out.append(PERF_LOG)
    print("\n".join(out))


if __name__ == "__main__":
    main()
