"""Paper Table 2: communication overhead to reach a target accuracy.

Per case: rounds-to-target (mean +- std over seeds) AND total uplink bytes
for FedAvg vs FedEntropy. Validated claims: (a) FedEntropy reaches the
target in no more rounds; (b) it uploads strictly fewer model bytes per
round on rounds where the judgment filters devices.
"""
from __future__ import annotations

import time

from .common import (
    ROUNDS, SEEDS, compile_cache_summary, mean_std, rounds_to_accuracy,
    run_method,
)

TARGETS = {"case1": 0.30, "case2": 0.40, "case3": 0.35}


def run(fast: bool = False):
    seeds = SEEDS[:1] if fast else SEEDS
    rounds = 15 if fast else ROUNDS
    rows, blob = [], {}
    for case, target in TARGETS.items():
        r2t = {"fedavg": [], "fedentropy": []}
        uplink = {"fedavg": [], "fedentropy": []}
        t0 = time.time()
        for seed in seeds:
            a = run_method(case, seed, method="fedavg",
                           rounds=rounds, eval_every=1)
            b = run_method(case, seed, method="fedentropy",
                           rounds=rounds, eval_every=1)
            r2t["fedavg"].append(rounds_to_accuracy(a["curve"], target))
            r2t["fedentropy"].append(rounds_to_accuracy(b["curve"], target))
            uplink["fedavg"].append(a["uplink_bytes"])
            uplink["fedentropy"].append(b["uplink_bytes"])
        dt = (time.time() - t0) * 1e6 / max(len(seeds) * 2 * rounds, 1)
        stats = {
            "rounds_to_target": {m: mean_std(v) for m, v in r2t.items()},
            "uplink_bytes": {m: mean_std(v) for m, v in uplink.items()},
            "target": target,
        }
        blob[case] = stats
        save = 1 - stats["uplink_bytes"]["fedentropy"][0] / max(
            stats["uplink_bytes"]["fedavg"][0], 1)
        rows.append((
            f"table2_{case}", f"{dt:.0f}",
            f"r2t_avg={stats['rounds_to_target']['fedavg'][0]:.1f}"
            f"|r2t_fe={stats['rounds_to_target']['fedentropy'][0]:.1f}"
            f"|byte_savings={save:.2%}"))
    blob["compile_cache"] = compile_cache_summary()
    return rows, blob
