"""Paper Table 3: FedEntropy's grouping plugged into other FL optimizers.

For each strategy S in {fedavg, fedprox, scaffold, moon}: accuracy of S
alone vs S + FedEntropy (judgment + pools on top of S's local update).
Validated claim: the grouping improves (or preserves) every optimizer —
the paper's orthogonality argument.

The fedcat row extends the table beyond the paper with the FedCAT
device-concatenation composition (arXiv 2202.12751): plain ``fedcat``
(entropy-grouped chains, no judgment) vs ``fedcat+maxent`` (maximum-
entropy judgment filtering chain membership before concatenation) — the
companion-paper synergy the ROADMAP calls for.

CI smoke: ``python -m benchmarks.synergy_table3 --fast --out
BENCH_synergy.json`` writes the JSON blob (including compile-cache stats)
as a per-commit artifact.
"""
from __future__ import annotations

import argparse
import json
import time

from .common import SEEDS, compile_cache_summary, mean_std, run_method

STRATEGIES = ("fedavg", "fedprox", "scaffold", "moon")
CASE = "case1"           # the paper's headline case for Table 3


def run(fast: bool = False):
    seeds = SEEDS[:1] if fast else SEEDS
    rounds = 15 if fast else 60
    rows, blob = [], {}
    variants = [(s, dict(method=s),
                 dict(method=s, selector="pools", judge="maxent"))
                for s in STRATEGIES]
    # beyond-paper row: concatenated chains, plain vs judgment-filtered
    variants.append(("fedcat", dict(method="fedcat"),
                     dict(method="fedcat+maxent")))
    for name, plain_kw, combo_kw in variants:
        plain, combo = [], []
        t0 = time.time()
        for seed in seeds:
            plain.append(run_method(
                CASE, seed, rounds=rounds, eval_every=0,
                **plain_kw)["final_accuracy"])
            combo.append(run_method(
                CASE, seed, rounds=rounds, eval_every=0,
                **combo_kw)["final_accuracy"])
        dt = (time.time() - t0) * 1e6 / (len(seeds) * 2 * rounds)
        p, c = mean_std(plain), mean_std(combo)
        blob[name] = {"plain": p, "with_fedentropy": c}
        rows.append((f"table3_{name}", f"{dt:.0f}",
                     f"plain={p[0]:.3f}|+fedentropy={c[0]:.3f}"
                     f"|delta={c[0] - p[0]:+.3f}"))
    blob["compile_cache"] = compile_cache_summary()
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="1 seed, 15 rounds (CI smoke)")
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_synergy.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print("compile cache:", blob["compile_cache"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
