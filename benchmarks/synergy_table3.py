"""Paper Table 3: FedEntropy's grouping plugged into other FL optimizers.

For each strategy S in {fedavg, fedprox, scaffold, moon}: accuracy of S
alone vs S + FedEntropy (judgment + pools on top of S's local update).
Validated claim: the grouping improves (or preserves) every optimizer —
the paper's orthogonality argument.
"""
from __future__ import annotations

import time

from .common import SEEDS, mean_std, run_method

STRATEGIES = ("fedavg", "fedprox", "scaffold", "moon")
CASE = "case1"           # the paper's headline case for Table 3


def run(fast: bool = False):
    seeds = SEEDS[:1] if fast else SEEDS
    rounds = 15 if fast else 60
    rows, blob = [], {}
    for strat in STRATEGIES:
        plain, combo = [], []
        t0 = time.time()
        for seed in seeds:
            plain.append(run_method(
                CASE, seed, method=strat, rounds=rounds,
                eval_every=0)["final_accuracy"])
            combo.append(run_method(
                CASE, seed, method=strat, selector="pools", judge="maxent",
                rounds=rounds, eval_every=0)["final_accuracy"])
        dt = (time.time() - t0) * 1e6 / (len(seeds) * 2 * rounds)
        p, c = mean_std(plain), mean_std(combo)
        blob[strat] = {"plain": p, "with_fedentropy": c}
        rows.append((f"table3_{strat}", f"{dt:.0f}",
                     f"plain={p[0]:.3f}|+fedentropy={c[0]:.3f}"
                     f"|delta={c[0] - p[0]:+.3f}"))
    return rows, blob
