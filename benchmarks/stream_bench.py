"""Streaming data plane A/B: device-resident vs host-resident cohorts.

Two measurements, one blob (``BENCH_stream.json``):

* **Paper-scale A/B (N=100)** — the same fedentropy composition runs on
  the pipelined engine with speculation against both planes; histories
  must stay int-identical (the plane-equivalence contract the golden
  tests hold), so the A/B isolates the data-plane cost: round latency,
  device-resident bytes, and — on the streaming side — the prefetch hit
  rate and the staging latency the speculation overlap actually hid.

* **Large-N smoke (N ≥ 50 000 synthetic)** — the residency claim at the
  scale the resident plane cannot reach: a 50k-client `HostCorpus`
  serves prefetched cohorts while its *device* footprint stays bounded
  by the cohort (O(|S_t|)), not the population (O(N)). The blob records
  the measured device/corpus byte ratio and asserts it; the prefetch
  counters report hit rate and overlap at this scale too.

  PYTHONPATH=src python -m benchmarks.stream_bench --smoke \
      --out BENCH_stream.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition
from repro.data.stream import HostCorpus
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import RuntimeConfig
from repro.models import cnn


def _time_rounds(server, rounds: int) -> float:
    server.round()                            # warmup: compile + dispatch
    jax.block_until_ready(server.global_params)
    t0 = time.perf_counter()
    for _ in range(rounds):
        server.round()
    jax.block_until_ready(server.global_params)
    return (time.perf_counter() - t0) / rounds


def _make_data(num_clients: int, batch: int):
    (xtr, ytr), _ = make_image_dataset(
        num_classes=10, train_per_class=2 * num_clients, test_per_class=2,
        hw=16, noise=0.9, seed=0)
    parts = partition("case1", ytr, num_clients, 10, seed=0)
    from repro.data.partition import stack_clients
    data = stack_clients(xtr, ytr, parts, batch_multiple=batch)
    params = cnn.init(jax.random.PRNGKey(0), image_hw=16, num_classes=10)
    return data, params


def _plane_ab(num_clients: int, rounds: int) -> dict:
    """Resident vs streaming, pipelined + speculation, int-equal history."""
    data, params = _make_data(num_clients, 10)
    cfg = fl.ServerConfig(num_clients=num_clients, participation=0.1,
                          seed=0)
    local = LocalSpec(epochs=1, batch_size=10)
    out, ints = {}, {}
    for plane in ("resident", "streaming"):
        server = fl.build("fedentropy", cnn.apply, params, dict(data),
                          cfg, local, engine="pipelined",
                          runtime=RuntimeConfig(speculate=True),
                          data_plane=plane)
        s_per_round = _time_rounds(server, rounds)
        rep = server.corpus.memory_report()
        rec = {"plane": plane, "s_per_round": s_per_round,
               "memory": rep,
               "spec_hits": int(sum(r["spec_hit"]
                                    for r in server.history))}
        if plane == "streaming":
            rec["prefetch"] = server.corpus.prefetch_stats()
        out[plane] = rec
        ints[plane] = [(r["selected"], r["positive"], r["negative"])
                       for r in server.history]
    # plane equivalence: the A/B timed identical verdict streams
    assert ints["resident"] == ints["streaming"], \
        "planes diverged — the A/B is meaningless"
    out["histories_int_equal"] = True
    return out


def _large_n_smoke(big_n: int, cohort: int, gathers: int) -> dict:
    """N >= 50k synthetic: device bytes stay O(|cohort|), never O(N)."""
    rng = np.random.default_rng(0)
    s, hw = 8, 8
    corpus = HostCorpus({
        "x": rng.integers(0, 256, (big_n, s, hw, hw, 1), dtype=np.uint8),
        "y": rng.integers(0, 10, (big_n, s)).astype(np.int32),
        "w": np.ones((big_n, s), np.float32),
    }, stats_chunk=4096)
    assert corpus.label_histograms().shape == (big_n, 10)
    t0 = time.perf_counter()
    cohorts = [rng.integers(0, big_n, cohort) for _ in range(gathers)]
    corpus.prefetch(cohorts[0])
    for i, idx in enumerate(cohorts):
        out = corpus.cohort(idx)              # consumes the staged upload
        if i + 1 < len(cohorts):
            corpus.prefetch(cohorts[i + 1])   # overlap the next one
        jax.block_until_ready(out["x"])
    dt = (time.perf_counter() - t0) / gathers
    rep = corpus.memory_report()
    pf = corpus.prefetch_stats()
    # the acceptance bound: what the device holds is the staged cohort
    # (uint8 storage == upload bytes; +1 in-flight prefetch), not N rows
    bound = 2 * corpus.cohort_nbytes(cohort)
    ok = rep["device_resident_bytes"] <= bound
    assert ok, (rep, bound)
    return {"num_clients": big_n, "cohort": cohort, "gathers": gathers,
            "s_per_gather": dt, "memory": rep, "prefetch": pf,
            "device_bytes_over_corpus":
                rep["device_resident_bytes"] / corpus.nbytes,
            "device_bytes_bound": bound,
            "device_bytes_o_cohort": bool(ok)}


def run(fast: bool = False, smoke: bool = False, num_clients: int = 100,
        rounds: int = 3, big_n: int = 50_000):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    if smoke:
        num_clients, rounds, big_n = 100, 3, 50_000   # pinned for CI
    elif fast:
        num_clients, rounds, big_n = 32, 3, 10_000
    m = max(1, num_clients // 10)
    ab = _plane_ab(num_clients, rounds)
    big = _large_n_smoke(big_n, cohort=max(m, 64), gathers=6)

    res, strm = ab["resident"], ab["streaming"]
    pf = strm["prefetch"]
    rows = [
        ("stream_resident", f"{res['s_per_round'] * 1e6:.0f}",
         f"{res['memory']['device_resident_bytes']}B resident"),
        ("stream_streaming", f"{strm['s_per_round'] * 1e6:.0f}",
         f"hit_rate={pf['hit_rate']:.2f}"),
        ("stream_overlap", f"{pf['overlap_s'] * 1e6:.0f}",
         f"{pf['stage_s'] * 1e6:.0f}us staged off-thread"),
        ("stream_large_n", f"{big['s_per_gather'] * 1e6:.0f}",
         f"{big['device_bytes_over_corpus']:.2e}x corpus bytes on device"),
    ]
    blob = {"plane_ab": ab, "large_n": big,
            "prefetch_hit_rate": pf["hit_rate"],
            "prefetch_overlap_s": pf["overlap_s"],
            "large_n_device_bytes_o_cohort":
                big["device_bytes_o_cohort"],
            "num_clients": num_clients, "cohort": m, "rounds": rounds,
            "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: N=100 A/B + 50k-client residency smoke")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--big-n", type=int, default=50_000)
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_stream.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke,
                     num_clients=args.clients, rounds=args.rounds,
                     big_n=args.big_n)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    big = blob["large_n"]
    print(f"large-N: {big['num_clients']} clients host-resident, "
          f"{big['memory']['device_resident_bytes']}B on device "
          f"({big['device_bytes_over_corpus']:.2e}x of the corpus); "
          f"prefetch hit rate {blob['prefetch_hit_rate']:.2f}, "
          f"overlap {blob['prefetch_overlap_s'] * 1e3:.1f}ms")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1, default=str)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
