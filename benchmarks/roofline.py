"""Roofline table from the multi-pod dry-run artifacts.

Reads results/dryrun_single_pod.json (written by
``python -m repro.launch.dryrun --out ...``); if absent, runs a small
subset in a subprocess (the dry-run must own a fresh process because it
forces 512 host devices before jax initializes).

Terms per (arch, shape) on the 16x16 single-pod mesh (TPU v5e constants:
197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):

  compute_s    = HLO dot-FLOPs(per device, loop-aware)   / 197e12
  memory_s     = HLO operand+result bytes(per device)    / 819e9
  collective_s = collective operand bytes(per device)    / 50e9
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SINGLE = "results/dryrun_single_pod.json"
FAST_COMBOS = [("qwen3-0.6b", "train_4k"), ("mamba2-130m", "decode_32k")]


def _ensure(fast: bool) -> list[dict]:
    if os.path.exists(SINGLE):
        with open(SINGLE) as f:
            return json.load(f)
    os.makedirs("results", exist_ok=True)
    records = []
    combos = FAST_COMBOS if fast else [("all", "all")]
    for arch, shape in combos:
        out = f"results/_roofline_tmp_{arch}_{shape}.json"
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--out", out],
            check=True, env={**os.environ,
                             "PYTHONPATH": os.environ.get("PYTHONPATH",
                                                          "src")})
        with open(out) as f:
            records += json.load(f)
    return records


def run(fast: bool = False):
    records = _ensure(fast)
    rows, blob = [], {"records": []}
    for r in records:
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append((f"roofline_{r['arch']}_{r['shape']}", "0",
                             "documented_skip"))
            continue
        t = r["roofline"]
        total = t["compute_s"] + t["memory_s"] + t["collective_s"]
        step_us = max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6
        frac = t["compute_s"] / max(total, 1e-12)
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}",
            f"{step_us:.0f}",
            f"dom={t['dominant']}|compute_frac={frac:.3f}"
            f"|useful={r['useful_flops_ratio']:.3f}"
            f"|coll_GB={r['collective_bytes_total'] / 1e9:.2f}"))
        blob["records"].append({k: r[k] for k in
                                ("arch", "shape", "roofline",
                                 "useful_flops_ratio",
                                 "collective_bytes_total",
                                 "collective_counts")})
    return rows, blob
