"""Clustered FL under drift: per-cluster accuracy vs a single center.

Three sequential drivers on the synthetic corpus with one seeded drift
event halfway through training (half the clients re-partitioned):

  * ``fedentropy``   — the paper's single-center run: one global model
                       absorbs both the pre- and post-drift populations;
  * ``ifca_maxent``  — K=3 ``ModelBank``, IFCA loss-argmin assignment
                       recomputed every round, max-entropy judgment and
                       aggregation per cluster;
  * ``fesem``        — K=3 sticky weight-distance assignment (FeSEM).

Each driver trains the same number of rounds over the same drift
schedule; the blob records test accuracy at every eval point — for the
clustered drivers both per-center and best-center — plus per-round
cluster occupancy and wall-clock. ``clustered_best_ge_single`` reports
whether the best bank center matches or beats the single-center run
after drift (informational, not a hard gate: at smoke scale the tiny
corpus is noisy).

Smoke mode (CI): 8 clients / 4 classes / 6 rounds, drift at round 2,
artifact written to ``BENCH_cluster.json``:

  PYTHONPATH=src python -m benchmarks.cluster_bench --smoke \
      --out BENCH_cluster.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import drift_schedule, partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import (
    disable_process_cache, enable_process_cache, process_cache,
)
from repro.models import cnn

# name -> (composition, num_clusters)
DRIVERS = {
    "fedentropy": ("fedentropy", 1),
    "ifca_maxent": ("ifca+maxent", 3),
    "fesem": ("fesem", 3),
}


def make_setup(num_clients: int, classes: int, hw: int, seed: int):
    """Raw x/y kept alongside the stacked corpus: ``drift_schedule``
    re-partitions from the full training pool, not the stacked rows."""
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=classes, train_per_class=60 if num_clients <= 8
        else 96, test_per_class=25, hw=hw, noise=1.0, seed=seed)
    parts = partition("case1", ytr, num_clients, classes, seed=seed)
    data = stack_clients(xtr, ytr, parts, batch_multiple=20)
    params = cnn.init(jax.random.PRNGKey(seed), image_hw=hw,
                      num_classes=classes)
    return (xtr, ytr), data, params, (jnp.asarray(xte), jnp.asarray(yte))


def _accuracies(server, xte, yte, k: int) -> dict:
    """Per-center + best accuracy (a single-center server reports one)."""
    per = [float(server.evaluate(xte, yte, center=c)["accuracy"])
           for c in range(k)]
    return {"per_center": per, "best": max(per)}


def run_driver(name: str, setup, *, num_clients: int, classes: int,
               rounds: int, drift_at: int, participation: float,
               local: LocalSpec, eval_every: int) -> dict:
    (xtr, ytr), data, params, (xte, yte) = setup
    comp, k = DRIVERS[name]
    drift = drift_schedule(
        xtr, ytr, num_clients, classes, at=drift_at, seed=0,
        samples_per_client=int(data["y"].shape[1]))
    server = fl.build(
        comp, cnn.apply, params, dict(data),
        fl.ServerConfig(num_clients=num_clients,
                        participation=participation, seed=0,
                        num_clusters=k),
        local, drift=drift)
    evals, occupancy = [], []
    t0 = time.perf_counter()
    for r in range(rounds):
        rec = server.round()
        if "cluster" in rec:
            occupancy.append(np.bincount(
                rec["cluster"], minlength=k).tolist())
        if (r + 1) % eval_every == 0 or r + 1 == rounds:
            evals.append({"round": r, "post_drift": r >= drift_at,
                          **_accuracies(server, xte, yte, k)})
    jax.block_until_ready(server.global_params)
    wall = time.perf_counter() - t0
    hist = server.history
    return {
        "driver": name, "composition": comp, "num_clusters": k,
        "rounds": rounds, "drift_round": drift_at, "wall_s": wall,
        "s_per_round": wall / rounds, "evals": evals,
        "final_acc_best": evals[-1]["best"],
        "final_acc_per_center": evals[-1]["per_center"],
        "occupancy": occupancy,
        "admitted": sum(len(h["positive"]) for h in hist),
        "rejected": sum(len(h["negative"]) for h in hist),
        "total_bytes": sum(h["comm"]["total_bytes"] for h in hist),
    }


def run(fast: bool = False, smoke: bool = False):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    if smoke:
        num_clients, classes, hw = 8, 4, 16
        rounds, drift_at, eval_every = 6, 2, 3
        participation, local = 0.5, LocalSpec(epochs=1, batch_size=20)
    elif fast:
        num_clients, classes, hw = 16, 6, 16
        rounds, drift_at, eval_every = 10, 5, 5
        participation, local = 0.25, LocalSpec(epochs=1, batch_size=24)
    else:
        # the paper scale the ISSUE names: N=100 clients, drift halfway
        num_clients, classes, hw = 100, 10, 16
        rounds, drift_at, eval_every = 20, 10, 5
        participation, local = 0.1, LocalSpec(epochs=2, batch_size=24)

    setup = make_setup(num_clients, classes, hw, seed=0)
    enable_process_cache(maxsize=32)
    try:
        results = [run_driver(name, setup, num_clients=num_clients,
                              classes=classes, rounds=rounds,
                              drift_at=drift_at,
                              participation=participation, local=local,
                              eval_every=eval_every)
                   for name in DRIVERS]
        cache_stats = process_cache().stats()
    finally:
        disable_process_cache()

    by_name = {r["driver"]: r for r in results}
    single = by_name["fedentropy"]["final_acc_best"]
    rows = []
    for r in results:
        rows.append((f"cluster_{r['driver']}",
                     f"{r['s_per_round'] * 1e6:.0f}",
                     f"{r['final_acc_best']:.4f}acc/K{r['num_clusters']}"))
    blob = {"results": results, "compile_cache": cache_stats,
            "num_clients": num_clients, "classes": classes,
            "rounds": rounds, "drift_round": drift_at,
            "participation": participation,
            "single_center_final_acc": single,
            "clustered_best_ge_single": any(
                r["final_acc_best"] >= single for r in results
                if r["num_clusters"] > 1),
            "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 8 clients, 6 rounds, drift at 2")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_cluster.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke)
    print("name,us_per_round,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print("clustered best >= single after drift:",
          blob["clustered_best_ge_single"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
