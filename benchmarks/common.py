"""Shared harness for the paper-table benchmarks.

All tables run the vmapped FedEntropy simulator on the synthetic
CIFAR-like dataset (offline container — see DESIGN.md §2.3) at reduced
scale: N=20 clients, |S_t|=5, T<=40 rounds, 6 classes. The paper's
*relative* orderings are what these tables validate.

Every ``run_method`` sweep shares compiled programs through the
process-level compile cache (ROADMAP item): the first run of a
(composition, shapes) pair compiles, every later one reuses the program.
Each record carries the per-run cache delta and first-round wall time, and
``compile_cache_summary()`` (appended to each table's JSON blob) reports
hits/misses plus the compile-time savings measured over that table's own
runs, per composition: mean cold first round minus mean warm first round,
times the number of warm runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import enable_process_cache, process_cache
from repro.models import cnn

# reduced-scale experiment constants (paper: N=100, C=0.1, T=1000)
NUM_CLIENTS = 32
PARTICIPATION = 0.156
ROUNDS = 60
CLASSES = 6
HW = 16
SEEDS = (0, 1, 2)


def make_setup(case: str, seed: int):
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=CLASSES, train_per_class=96, test_per_class=25,
        hw=HW, noise=1.4, seed=seed)
    parts = partition(case, ytr, NUM_CLIENTS, CLASSES, seed=seed)
    data = stack_clients(xtr, ytr, parts, batch_multiple=24)
    params = cnn.init(jax.random.PRNGKey(seed), image_hw=HW,
                      num_classes=CLASSES)
    return data, params, (jnp.asarray(xte), jnp.asarray(yte))


# first-round wall times per composition and cache outcome, feeding the
# savings estimate; drained by compile_cache_summary() so each table's
# blob attributes savings to its own runs only
_FIRST_ROUND_S: dict[str, dict[str, list[float]]] = {}


def run_method(case: str, seed: int, *, method: str = "fedentropy",
               selector: str | None = None, judge: str | None = None,
               rounds: int = ROUNDS, eval_every: int = 5):
    """Run one (composition, case, seed); returns accuracy curve + comm.

    ``method`` is a ``repro.fl`` composition name ("fedentropy", "fedavg",
    "fedprox", "scaffold", "moon", "fedcat", "fedcat+maxent");
    ``selector``/``judge`` override single axes, e.g. ``method="scaffold",
    selector="pools", judge="maxent"`` is Table 3's SCAFFOLD+FedEntropy and
    ``method="fedentropy", selector="uniform"`` is Fig. 3b's no-pools
    ablation.
    """
    cache = enable_process_cache(maxsize=32)
    before = dict(cache.stats())
    data, params, test = make_setup(case, seed)
    server = fl.build(
        method, cnn.apply, params, data,
        fl.ServerConfig(num_clients=NUM_CLIENTS,
                        participation=PARTICIPATION, seed=seed),
        LocalSpec(epochs=2, batch_size=24, lr=0.05),
        selector=selector, judge=judge)
    # time the first round (compile or cache-hit + dispatch) through a
    # one-shot wrapper so the fit()/tail eval cadence stays exactly as
    # recorded in historical bench blobs
    first = {}
    orig_round = server.round

    def timed_first_round():
        t = time.time()
        rec = orig_round()
        first["s"] = time.time() - t
        del server.round            # restore the bound method
        return rec

    server.round = timed_first_round
    t0 = time.time()
    curve = server.fit(max(rounds - 10, 0), eval_every=eval_every,
                       eval_data=test)
    # paper Sec. 4.2: report the average accuracy over the last ten rounds
    tail = []
    for _ in range(min(10, rounds)):
        server.round()
        tail.append(server.evaluate(*test)["accuracy"])
        if eval_every:
            curve.append({"round": server.round_idx, "accuracy": tail[-1]})
    first_round_s = first.get("s", 0.0)
    delta = {k: cache.stats()[k] - before[k] for k in ("hits", "misses")}
    obs = _FIRST_ROUND_S.setdefault(method, {"cold": [], "warm": []})
    obs["cold" if delta["misses"] else "warm"].append(first_round_s)
    return {
        "case": case, "seed": seed, "method": method,
        "selector": selector, "judge": judge,
        "final_accuracy": float(np.mean(tail)),
        "curve": [(c["round"], c["accuracy"]) for c in curve],
        "uplink_bytes": fl.total_uplink_bytes(server.history),
        "rounds": rounds,
        "wall_s": time.time() - t0,
        "first_round_s": first_round_s,
        "compile_cache": delta,
    }


def compile_cache_summary() -> dict | None:
    """Cache stats + measured compile-time savings since the last summary.

    Cold/warm first-round means are kept per composition (a fedcat chain
    compile is not comparable to a fedavg one) and the accumulator drains
    on read, so every table's JSON blob reports the savings of its own
    sweep: sum over compositions of (cold mean - warm mean) * warm runs.
    """
    cache = process_cache()
    if cache is None:
        return None
    out = dict(cache.stats())
    per, saved = {}, None
    for method, obs in _FIRST_ROUND_S.items():
        cold, warm = obs["cold"], obs["warm"]
        per[method] = {
            "cold_first_round_s": float(np.mean(cold)) if cold else None,
            "warm_first_round_s": float(np.mean(warm)) if warm else None,
            "cold_runs": len(cold), "warm_runs": len(warm),
        }
        if cold and warm:
            saved = (saved or 0.0) + float(
                (np.mean(cold) - np.mean(warm)) * len(warm))
    out["first_round_s_by_method"] = per
    out["compile_s_saved"] = saved
    _FIRST_ROUND_S.clear()
    return out


def rounds_to_accuracy(curve, target):
    for r, acc in curve:
        if acc >= target:
            return r
    return None


def mean_std(vals):
    v = np.asarray([x for x in vals if x is not None], np.float64)
    if len(v) == 0:
        return float("nan"), float("nan")
    return float(v.mean()), float(v.std())
