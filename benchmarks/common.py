"""Shared harness for the paper-table benchmarks.

All tables run the vmapped FedEntropy simulator on the synthetic
CIFAR-like dataset (offline container — see DESIGN.md §2.3) at reduced
scale: N=20 clients, |S_t|=5, T<=40 rounds, 6 classes. The paper's
*relative* orderings are what these tables validate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.partition import partition, stack_clients
from repro.data.synthetic import make_image_dataset
from repro.models import cnn

# reduced-scale experiment constants (paper: N=100, C=0.1, T=1000)
NUM_CLIENTS = 32
PARTICIPATION = 0.156
ROUNDS = 60
CLASSES = 6
HW = 16
SEEDS = (0, 1, 2)


def make_setup(case: str, seed: int):
    (xtr, ytr), (xte, yte) = make_image_dataset(
        num_classes=CLASSES, train_per_class=96, test_per_class=25,
        hw=HW, noise=1.4, seed=seed)
    parts = partition(case, ytr, NUM_CLIENTS, CLASSES, seed=seed)
    data = stack_clients(xtr, ytr, parts, batch_multiple=24)
    params = cnn.init(jax.random.PRNGKey(seed), image_hw=HW,
                      num_classes=CLASSES)
    return data, params, (jnp.asarray(xte), jnp.asarray(yte))


def run_method(case: str, seed: int, *, method: str = "fedentropy",
               selector: str | None = None, judge: str | None = None,
               rounds: int = ROUNDS, eval_every: int = 5):
    """Run one (composition, case, seed); returns accuracy curve + comm.

    ``method`` is a ``repro.fl`` composition name ("fedentropy", "fedavg",
    "fedprox", "scaffold", "moon"); ``selector``/``judge`` override single
    axes, e.g. ``method="scaffold", selector="pools", judge="maxent"``
    is Table 3's SCAFFOLD+FedEntropy and ``method="fedentropy",
    selector="uniform"`` is Fig. 3b's no-pools ablation.
    """
    data, params, test = make_setup(case, seed)
    server = fl.build(
        method, cnn.apply, params, data,
        fl.ServerConfig(num_clients=NUM_CLIENTS,
                        participation=PARTICIPATION, seed=seed),
        LocalSpec(epochs=2, batch_size=24, lr=0.05),
        selector=selector, judge=judge)
    t0 = time.time()
    curve = server.fit(max(rounds - 10, 0), eval_every=eval_every,
                       eval_data=test)
    # paper Sec. 4.2: report the average accuracy over the last ten rounds
    tail = []
    for _ in range(min(10, rounds)):
        server.round()
        tail.append(server.evaluate(*test)["accuracy"])
        if eval_every:
            curve.append({"round": server.round_idx, "accuracy": tail[-1]})
    return {
        "case": case, "seed": seed, "method": method,
        "selector": selector, "judge": judge,
        "final_accuracy": float(np.mean(tail)),
        "curve": [(c["round"], c["accuracy"]) for c in curve],
        "uplink_bytes": fl.total_uplink_bytes(server.history),
        "rounds": rounds,
        "wall_s": time.time() - t0,
    }


def rounds_to_accuracy(curve, target):
    for r, acc in curve:
        if acc >= target:
            return r
    return None


def mean_std(vals):
    v = np.asarray([x for x in vals if x is not None], np.float64)
    if len(v) == 0:
        return float("nan"), float("nan")
    return float(v.mean()), float(v.std())
