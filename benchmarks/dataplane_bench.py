"""Data-plane A/B: host-slice (seed-era) vs device-resident ClientCorpus.

Paper-scale smoke (ROADMAP item): N=100 clients, pipelined engine, the
synthetic CIFAR-like corpus at reduced resolution. Two servers run the
same composition:

* ``host-slice`` — the seed-era data plane, re-created for the A/B: the
  stacked corpus lives in host numpy and every round slices the cohort
  on host and ships it to device (bytes/round = the full cohort).
* ``corpus`` — the ``ClientCorpus`` data plane: the corpus is device-
  resident (storage dtype), the cohort is a jitted on-device gather,
  and only the ``idx`` vector crosses the host→device boundary.

A second A/B covers the *uneven-mesh placement* (N % devices != 0 — the
paper's N=100 on any realistic accelerator count): the PR-4-era fallback
replicated the whole corpus onto every mesh device, the padded-shard
layout pads the client axis to the next mesh multiple and shards
``P("clients")``. The blob records per-device resident bytes and round
latency for both layouts; on a single device the comparison degenerates
(both layouts coincide) — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (as the CI job
does) to measure the real 8-way split.

The JSON blob (``BENCH_dataplane.json``) records per-round host→device
bytes for both paths, measured round wall-clock, and the resident-memory
ratio of uint8 vs float32 storage for the same image corpus — the two
levers the corpus refactor pulls.

  PYTHONPATH=src python -m benchmarks.dataplane_bench --smoke \
      --out BENCH_dataplane.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.fl as fl
from repro.core.strategies import LocalSpec
from repro.data.corpus import CLIENT_AXIS, ClientCorpus, Normalize
from repro.data.partition import partition
from repro.data.synthetic import make_image_dataset
from repro.fl.runtime import PipelinedServer, RuntimeConfig
from repro.models import cnn


class ReplicatedCorpus(ClientCorpus):
    """The PR-4-era placement, preserved as the uneven-mesh A/B baseline:
    ``N % mesh != 0`` silently fell back to replicating the whole corpus
    onto every mesh device (every device held all N shards)."""

    def shard(self, mesh, axis: str = CLIENT_AXIS) -> "ClientCorpus":
        if self._mesh is mesh:
            return self
        from jax.sharding import NamedSharding, PartitionSpec as P
        size = mesh.shape[axis]
        for k, v in self._arrays.items():
            spec = P(axis) if v.shape[0] % size == 0 else P()
            self._arrays[k] = jax.device_put(v, NamedSharding(mesh, spec))
        self._mesh = mesh
        return self


class HostSliceServer(PipelinedServer):
    """Seed-era data plane, preserved for the A/B baseline: numpy-resident
    corpus, per-round host slice + full-cohort H2D transfer."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        host = self.corpus.as_numpy()
        if self.corpus.transform is not None:
            # the seed-era layout stored images post-normalization, float32
            host["x"] = np.asarray(self.corpus.transform(
                jnp.asarray(host["x"])))
        self._host = host
        self.h2d_bytes_per_round = 0

    def _run_cohort(self, sel, selector, global_params=None):
        gp = self.global_params if global_params is None else global_params
        idx = np.asarray(sel)
        data = {k: v[idx] for k, v in self._host.items()}
        self.h2d_bytes_per_round = sum(v.nbytes for v in data.values())
        prev_p, c_loc, c_glob = self.strategy.client_inputs(self.state, idx)
        return self._client_fn()(gp, data, prev_p, c_loc, c_glob)


def _make_corpus(num_clients: int, samples_multiple: int, seed: int = 0):
    classes, hw = 10, 16
    per_class = max(2 * num_clients, 40)
    (xtr, ytr), _ = make_image_dataset(
        num_classes=classes, train_per_class=per_class, test_per_class=10,
        hw=hw, noise=0.9, seed=seed)
    parts = partition("case1", ytr, num_clients, classes, seed=seed)
    corpus = ClientCorpus.from_parts(xtr, ytr, parts,
                                     batch_multiple=samples_multiple)
    params = cnn.init(jax.random.PRNGKey(seed), image_hw=hw,
                      num_classes=classes)
    return corpus, params, (xtr, ytr, parts)


def _prove_resident_gather(corpus, m: int) -> None:
    """Regression tripwire for the corpus path: with ``idx`` already on
    device (replicated over the corpus mesh when sharded), a cohort
    gather must move zero bytes across the host boundary — any
    reintroduced numpy fallback or host round-trip in the gather path
    raises under the transfer guard and fails the bench."""
    idx = corpus.put_index(np.arange(m, dtype=np.int32))
    corpus.cohort(idx)                      # compile outside the guard
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(corpus.cohort(idx)["x"])


def _uneven_ab(xtr, ytr, parts, params, cfg, local, rounds: int) -> dict:
    """Replicated (PR-4 fallback) vs padded-shard placement on the current
    mesh: per-device resident corpus bytes and measured round latency.

    With N % devices != 0 the replicated baseline holds the full corpus on
    EVERY device; the padded layout holds ~ceil(N/devices) client rows per
    device (13/100 of the replicated total at N=100 on 8 devices)."""
    from jax.sharding import PartitionSpec as P
    layouts = {}
    for name, cls in (("replicated", ReplicatedCorpus),
                      ("padded", ClientCorpus)):
        corpus = cls.from_parts(xtr, ytr, parts, batch_multiple=20)
        server = fl.build("fedentropy", cnn.apply, params, corpus, cfg,
                          local, engine="pipelined",
                          runtime=RuntimeConfig(shard=True))
        s_per_round = _time_rounds(server, rounds)
        layouts[name] = {
            "layout": name, "s_per_round": s_per_round,
            "device_nbytes": corpus.device_nbytes(),
            "total_nbytes": corpus.nbytes,
            "padded_clients": corpus.padded_num_clients,
            "client_sharded": all(v.sharding.spec == P(CLIENT_AXIS)
                                  for v in corpus.values()),
        }
    return {
        "devices": len(jax.devices()),
        "uneven": cfg.num_clients % len(jax.devices()) != 0,
        "layouts": list(layouts.values()),
        # the memory lever: fraction of the replicated per-device bytes
        # the padded-shard layout keeps resident on the busiest device
        "device_bytes_ratio": layouts["padded"]["device_nbytes"]
        / max(layouts["replicated"]["device_nbytes"], 1),
    }


def _time_rounds(server, rounds: int) -> float:
    server.round()                            # warmup: compile + dispatch
    jax.block_until_ready(server.global_params)
    t0 = time.perf_counter()
    for _ in range(rounds):
        server.round()
    jax.block_until_ready(server.global_params)
    return (time.perf_counter() - t0) / rounds


def run(fast: bool = False, smoke: bool = False, num_clients: int = 100,
        rounds: int = 3):
    """Benchmark-harness entry: returns (csv_rows, json_blob)."""
    if smoke:
        num_clients, rounds = 100, 3        # paper-scale N, pinned for CI
    elif fast:
        num_clients, rounds = 32, 3
    local = LocalSpec(epochs=1, batch_size=20)
    corpus, params, (xtr, ytr, parts) = _make_corpus(num_clients, 20)
    # dtype-lever baseline bytes, captured BEFORE any server shards (and
    # possibly pads) the corpus: the uint8 ratio compares equal-N layouts
    f32_nbytes = corpus.nbytes
    cfg = fl.ServerConfig(num_clients=num_clients, participation=0.1, seed=0)
    m = max(1, int(round(num_clients * cfg.participation)))

    results = {}
    for name in ("host-slice", "corpus"):
        engine = HostSliceServer if name == "host-slice" else "pipelined"
        server = fl.build("fedentropy", cnn.apply, params, corpus, cfg,
                          local, engine=engine, runtime=RuntimeConfig())
        if name == "corpus":
            assert all(isinstance(v, jax.Array)
                       for v in server.corpus.values())
            _prove_resident_gather(server.corpus, m)
        s_per_round = _time_rounds(server, rounds)
        if name == "host-slice":
            bytes_round = server.h2d_bytes_per_round
            basis = "measured: cohort arrays shipped per round"
        else:
            # computed, not measured: the idx vector (int32) is the only
            # per-round H2D payload — _prove_resident_gather above raises
            # if the gather itself ever touches the host again
            bytes_round = m * np.dtype(np.int32).itemsize
            basis = ("computed: idx vector only (corpus device-resident; "
                     "gather verified transfer-free under transfer_guard)")
        results[name] = {"engine": name, "s_per_round": s_per_round,
                         "h2d_bytes_per_round": int(bytes_round),
                         "h2d_basis": basis, "rounds": rounds}

    # resident-memory lever: the same images stored uint8 vs float32
    lo, hi = xtr.min(), xtr.max()
    x8 = np.clip((xtr - lo) / max(hi - lo, 1e-9) * 255, 0, 255
                 ).astype(np.uint8)
    c8 = ClientCorpus.from_parts(
        x8, ytr, parts, batch_multiple=20,
        transform=Normalize(scale=(hi - lo) / 255.0, mean=(-lo,)))
    c8.cohort(np.arange(m))                    # prove the gather traces
    mem = {"float32_bytes": f32_nbytes, "uint8_bytes": c8.nbytes,
           "ratio": f32_nbytes / max(c8.nbytes, 1)}

    # uneven-mesh placement A/B: replicated fallback vs padded shards
    uneven = _uneven_ab(xtr, ytr, parts, params, cfg, local, rounds)

    base = results["host-slice"]
    cor = results["corpus"]
    reduction = base["h2d_bytes_per_round"] / max(
        cor["h2d_bytes_per_round"], 1)
    pad = next(l for l in uneven["layouts"] if l["layout"] == "padded")
    rows = [
        ("dataplane_host_slice", f"{base['s_per_round'] * 1e6:.0f}",
         f"{base['h2d_bytes_per_round']}B/round"),
        ("dataplane_corpus", f"{cor['s_per_round'] * 1e6:.0f}",
         f"{cor['h2d_bytes_per_round']}B/round"),
        ("dataplane_h2d_reduction", "0", f"{reduction:.0f}x"),
        ("dataplane_uneven_padded", f"{pad['s_per_round'] * 1e6:.0f}",
         f"{uneven['device_bytes_ratio']:.2f}x device bytes vs replicated"),
    ]
    blob = {"results": list(results.values()),
            "h2d_reduction": reduction, "resident_memory": mem,
            "uneven_mesh": uneven,
            "num_clients": num_clients, "cohort": m, "rounds": rounds,
            "devices": len(jax.devices()),
            "backend": jax.default_backend()}
    return rows, blob


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: N=100 clients, 3 rounds (paper-scale N)")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--out", default="",
                    help="write the JSON blob here (BENCH_dataplane.json)")
    args = ap.parse_args()
    rows, blob = run(fast=args.fast, smoke=args.smoke,
                     num_clients=args.clients, rounds=args.rounds)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    print(f"h2d: {blob['h2d_reduction']:.0f}x fewer bytes/round; "
          f"resident uint8 {blob['resident_memory']['ratio']:.1f}x smaller")
    u = blob["uneven_mesh"]
    print(f"uneven mesh ({blob['num_clients']} clients / {u['devices']} "
          f"devices): padded layout keeps "
          f"{u['device_bytes_ratio']:.2f}x of the replicated per-device "
          f"bytes resident")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
